"""Megastep dispatch: the single-dispatch tick (DESIGN.md §12).

Covers the acceptance criteria of the device-resident tick loop:
  * the single-dispatch invariant — a warm fused-megastep drain issues at
    most one device program per tick (``dispatches_per_tick`` ~ 1.0),
  * verdict-carry correctness — megastep, batched, and legacy dispatch
    produce the same logical outcome on identical seeds (and megastep vs
    batched the bit-identical physical pool), with per-request accounting
    closure on every path,
  * jit-cache stability — a retry storm's fragmented batch lengths all
    round up to the shared floored bucket, so megastep compiles a bounded
    number of variants after warmup,
  * the config tri-state (``LeapConfig.fused_dispatch`` / ``dispatch_mode``)
    including the ppermute fallback.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LeapConfig,
    MigrationDriver,
    PoolConfig,
    init_state,
    leap_write,
    migrator,
)


def make(n_regions=2, slots=64, n_blocks=32, block_shape=(4,), seed=0):
    cfg = PoolConfig(n_regions, slots, block_shape)
    state = init_state(cfg, n_blocks, np.zeros(n_blocks, np.int32))
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n_blocks,) + block_shape).astype(np.float32)
    state = leap_write(state, jnp.arange(n_blocks), jnp.asarray(data))
    return cfg, state, data


def _run_interleaved(mode, seed=3, n_blocks=32):
    """Identical request + write schedule under a given dispatch mode."""
    cfg, state, data = make(n_blocks=n_blocks, slots=n_blocks * 2, seed=seed)
    drv = MigrationDriver(
        state,
        cfg,
        LeapConfig(
            initial_area_blocks=8,
            chunk_blocks=4,
            budget_blocks_per_tick=8,
            max_attempts_before_force=3,
            fused_dispatch=mode,
        ),
    )
    session = drv.default_session()
    session.leap(np.arange(n_blocks), 1)
    rng = np.random.default_rng(seed)
    expected = data.copy()
    steps = 0
    while not drv.done and steps < 1000:
        drv.tick()
        ids = rng.choice(n_blocks, size=2, replace=False)
        vals = rng.normal(size=(2, 4)).astype(np.float32)
        drv.write(jnp.asarray(ids), jnp.asarray(vals))
        expected[ids] = vals
        steps += 1
    assert session.drain()
    return drv, expected


# ---------------------------------------------------------------------------
# Config tri-state
# ---------------------------------------------------------------------------


def test_dispatch_mode_tri_state():
    assert LeapConfig().dispatch_mode == "megastep"
    assert LeapConfig(fused_dispatch=True).dispatch_mode == "megastep"
    assert LeapConfig(fused_dispatch="megastep").dispatch_mode == "megastep"
    assert LeapConfig(fused_dispatch="batched").dispatch_mode == "batched"
    assert LeapConfig(fused_dispatch=False).dispatch_mode == "legacy"
    assert LeapConfig(fused_dispatch="legacy").dispatch_mode == "legacy"
    with pytest.raises(ValueError):
        LeapConfig(fused_dispatch="warp")


def test_megastep_falls_back_to_batched_on_ppermute():
    """shard_map programs have static (src, dst) endpoints: they cannot fuse
    into one variant-stable program, so megastep demotes to batched there."""
    cfg = LeapConfig(fused_dispatch=True, backend="ppermute")
    assert cfg.dispatch_mode == "batched"
    # an explicit legacy request survives the backend
    assert LeapConfig(fused_dispatch=False, backend="ppermute").dispatch_mode == "legacy"


# ---------------------------------------------------------------------------
# The single-dispatch invariant
# ---------------------------------------------------------------------------


def test_single_dispatch_per_tick_on_drain():
    """fig9-style drain under megastep: at most ONE device program per tick
    (idle/harvest-only ticks dispatch nothing, so the ratio sits at or just
    under 1.0 — never above)."""
    cfg, state, _ = make(n_blocks=128, slots=256)
    drv = MigrationDriver(
        state,
        cfg,
        LeapConfig(initial_area_blocks=64, chunk_blocks=16, budget_blocks_per_tick=64),
    )
    drv.default_session().leap(np.arange(128), 1)
    assert drv.drain()
    assert drv.stats.ticks > 0
    assert drv.stats.dispatches <= drv.stats.ticks
    assert 0.0 < drv.stats.dispatches_per_tick <= 1.0
    assert drv.verify_mirror()


def test_idle_ticks_dispatch_nothing():
    cfg, state, _ = make(n_blocks=8, slots=16)
    drv = MigrationDriver(state, cfg, LeapConfig())
    for _ in range(5):
        drv.tick()
    assert drv.stats.ticks == 5 and drv.stats.dispatches == 0


# ---------------------------------------------------------------------------
# Verdict-carry correctness across dispatch generations
# ---------------------------------------------------------------------------


def test_megastep_matches_batched_and_legacy_under_writes():
    drv_m, exp_m = _run_interleaved("megastep")
    drv_b, exp_b = _run_interleaved("batched")
    drv_l, exp_l = _run_interleaved("legacy")
    for drv, expected in ((drv_m, exp_m), (drv_b, exp_b), (drv_l, exp_l)):
        assert (drv.host_placement() == 1).all()
        assert drv.verify_mirror()
        np.testing.assert_array_equal(np.asarray(drv.read(np.arange(32))), expected)
    # same write schedule => identical logical outcome on all three paths
    np.testing.assert_array_equal(exp_m, exp_b)
    np.testing.assert_array_equal(exp_m, exp_l)
    # megastep and batched make byte-identical scheduling decisions, so the
    # physical pools (slot placement included) match bit for bit
    np.testing.assert_array_equal(np.asarray(drv_m.state.pool), np.asarray(drv_b.state.pool))
    np.testing.assert_array_equal(np.asarray(drv_m.state.table), np.asarray(drv_b.state.table))
    # and the megastep pays no more dispatches than either prior generation
    assert drv_m.stats.dispatches <= drv_b.stats.dispatches
    assert drv_m.stats.dispatches < drv_l.stats.dispatches


def test_accounting_closure_every_mode():
    """committed + forced + cancelled == requested at termination, and the
    retry traffic the stats report covers the re-copied bytes, on all paths."""
    for mode in ("megastep", "batched", "legacy"):
        drv, _ = _run_interleaved(mode, seed=7)
        for req in drv.requests.values():
            assert req.done
            assert req.committed + req.forced + req.cancelled == req.requested
        s = drv.stats
        assert s.blocks_migrated + s.blocks_forced + s.blocks_cancelled == s.blocks_requested


def test_megastep_huge_tier_drain():
    """Two-tier pool under megastep: grouped commits and contiguous-run
    copies ride the same single dispatch."""
    G = 4
    cfg = PoolConfig(2, 32, (4,), huge_factor=G)
    n_blocks = 16
    state = init_state(cfg, n_blocks, np.zeros(n_blocks, np.int32))
    rng = np.random.default_rng(5)
    data = rng.normal(size=(n_blocks, 4)).astype(np.float32)
    state = leap_write(state, jnp.arange(n_blocks), jnp.asarray(data))
    drv = MigrationDriver(state, cfg, LeapConfig(initial_area_blocks=8))
    drv.adopt_huge(np.arange(n_blocks // G))
    drv.default_session().leap(np.arange(n_blocks), 1)
    assert drv.drain()
    assert (drv.host_placement() == 1).all()
    assert drv.verify_mirror()
    np.testing.assert_array_equal(np.asarray(drv.read(np.arange(n_blocks))), data)
    assert drv.stats.huge_areas_committed > 0
    assert 0.0 < drv.stats.dispatches_per_tick <= 1.0


# ---------------------------------------------------------------------------
# Jit-cache stability under a retry storm
# ---------------------------------------------------------------------------


def test_megastep_cache_stable_under_retry_storm():
    """However the splitter fragments the work, every megastep operand pads
    to the budget-floored shared bucket: the storm compiles a handful of
    variants, not one per batch-length combination."""
    before = migrator.program_cache_sizes()["megastep"]
    for seed in (21, 22):
        cfg, state, data = make(n_blocks=64, slots=128, seed=seed)
        drv = MigrationDriver(
            state,
            cfg,
            LeapConfig(
                initial_area_blocks=16,
                budget_blocks_per_tick=64,
                max_attempts_before_force=4,
            ),
        )
        drv.default_session().leap(np.arange(64), 1)
        rng = np.random.default_rng(seed)
        steps = 0
        while not drv.done and steps < 2000:
            drv.tick()
            ids = rng.choice(64, size=4, replace=False)
            drv.write(jnp.asarray(ids), jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32)))
            steps += 1
        assert drv.drain()
        assert drv.verify_mirror()
        assert drv.stats.dirty_rejections > 0, "workload must exercise splitting"
    after = migrator.program_cache_sizes()["megastep"]
    # the floored bucket admits the steady-state shape plus at most the
    # force-overflow shape (forces are budget-exempt, so a force batch can
    # exceed the budget floor and round up one bucket)
    assert after - before <= 3, (before, after)
    # driver-level stat agrees: bounded compiles despite the length storm
    assert drv.stats.jit_cache_misses <= 6


def test_megastep_warm_ticks_do_not_recompile():
    """Second drain on an identically shaped pool: zero new megastep
    variants (the warm path the fig9 bench gates)."""
    cfg, state, _ = make(n_blocks=32, slots=64, seed=31)
    drv = MigrationDriver(state, cfg, LeapConfig(budget_blocks_per_tick=16))
    drv.default_session().leap(np.arange(32), 1)
    assert drv.drain()
    before = migrator.program_cache_sizes()["megastep"]
    cfg2, state2, _ = make(n_blocks=32, slots=64, seed=32)
    drv2 = MigrationDriver(state2, cfg2, LeapConfig(budget_blocks_per_tick=16))
    drv2.default_session().leap(np.arange(32), 0)  # opposite direction, same shapes
    drv2.default_session().leap(np.arange(32), 1)
    assert drv2.drain()
    assert migrator.program_cache_sizes()["megastep"] == before
    assert drv2.stats.jit_cache_misses == 0
