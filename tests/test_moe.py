"""MoE routing unit tests: top-k selection, capacity dropping, grouped
routing equivalence, combine-weight correctness vs a brute-force oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig, get_config
from repro.configs.smoke import reduce
from repro.models.moe import _pick_groups, capacity, moe_ffn, route


def brute_force_route(gates, k, cap, norm):
    """Reference: rank-major greedy capacity assignment."""
    t, e = gates.shape
    topi = np.argsort(-gates, axis=1)[:, :k]
    topv = np.take_along_axis(gates, topi, axis=1)
    if norm:
        topv = topv / (topv.sum(1, keepdims=True) + 1e-9)
    combine = np.zeros((t, e, cap))
    fill = np.zeros(e, np.int64)
    for r in range(k):  # rank-major, then token order (cumsum semantics)
        for tok in range(t):
            ex = topi[tok, r]
            if fill[ex] < cap:
                combine[tok, ex, fill[ex]] = topv[tok, r]
                fill[ex] += 1
    return combine


def test_route_matches_brute_force():
    rng = np.random.default_rng(0)
    t, e, k = 16, 4, 2
    raw = rng.normal(size=(t, e))
    gates = jnp.asarray(jax.nn.softmax(jnp.asarray(raw), -1))
    mc = MoEConfig(n_experts=e, top_k=k, d_ff=8)
    cap = 5
    dispatch, combine, aux = route(np.asarray(gates) * 1.0, mc, cap)
    want = brute_force_route(np.asarray(gates), k, cap, mc.norm_topk)
    np.testing.assert_allclose(np.asarray(combine), want, atol=1e-6)
    # dispatch is the support of combine
    np.testing.assert_array_equal(
        np.asarray(dispatch), np.asarray(combine) > 0
    )
    assert float(aux) > 0


def test_capacity_drops_overflow():
    # all tokens want expert 0; capacity 2 keeps exactly 2
    gates = jnp.asarray(np.tile([0.97, 0.01, 0.01, 0.01], (8, 1)), jnp.float32)
    mc = MoEConfig(n_experts=4, top_k=1, d_ff=8, norm_topk=False)
    dispatch, combine, _ = route(gates, mc, 2)
    assert int(dispatch[:, 0].sum()) == 2


def test_grouped_vs_global_with_headroom():
    """With capacity ample enough that nothing drops, grouped routing equals
    ungrouped (groups only change the capacity partitioning)."""
    cfg = dataclasses.replace(
        reduce(get_config("dbrx_132b")),
        n_layers=1,
    )
    mcg = dataclasses.replace(cfg.moe, groups=4, capacity_factor=8.0)
    cfg_g = dataclasses.replace(cfg, moe=mcg)
    mc1 = dataclasses.replace(cfg.moe, groups=1, capacity_factor=8.0)
    cfg_1 = dataclasses.replace(cfg, moe=mc1)
    from repro.models.moe import moe_init

    params = moe_init(jax.random.key(0), cfg_g)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    y_g, _ = moe_ffn(x, params, cfg_g)
    y_1, _ = moe_ffn(x, params, cfg_1)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_1), rtol=2e-5, atol=2e-5)


def test_pick_groups():
    assert _pick_groups(4096, 64) == 64
    assert _pick_groups(100, 64) == 50
    assert _pick_groups(7, 4) == 1
    assert capacity(MoEConfig(8, 2, 4), 64) == 20
