"""Hypothesis property test: decode is invariant under ANY interleaving of
decode steps, migration ticks, and rebalance requests.

Kept separate from test_serving.py so the main suite collects when the
optional ``hypothesis`` dev dependency (requirements-dev.txt) is absent.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import LeapConfig

# Reuse the module-scoped model fixture and engine helper; importing a fixture
# into a module's namespace registers it for that module's tests.
from test_serving import _engine, setup  # noqa: F401


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    schedule=st.lists(st.sampled_from(["decode", "tick", "rebalance"]), min_size=4, max_size=14),
)
def test_property_decode_invariant_under_any_migration_schedule(setup, seed, schedule):
    """Property: for ANY interleaving of decode steps, migration ticks, and
    rebalance requests, the decoded tokens equal the no-migration run."""
    cfg, params = setup
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 10))) for _ in range(2)]

    def run(with_migration: bool):
        eng = _engine(cfg, params, leap=LeapConfig(
            initial_area_blocks=2, chunk_blocks=1, budget_blocks_per_tick=1,
            max_attempts_before_force=2,
        ))
        sids = [eng.admit(p, region=i % 2) for i, p in enumerate(prompts)]
        toks = [[eng.seqs[s].tokens[-1]] for s in sids]
        flip = 0
        for op in schedule:
            if op == "decode":
                outs = eng.decode(sids)
                for i, t in enumerate(outs):
                    toks[i].append(t)
            elif with_migration and op == "tick":
                eng.tick()
            elif with_migration and op == "rebalance":
                eng.rebalance(sids[flip % 2], dst_region=(flip + 1) % 2)
                flip += 1
        if with_migration:
            assert eng.drain()
        return toks

    assert run(True) == run(False)
