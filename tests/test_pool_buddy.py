"""Buddy allocator unit tests: split/coalesce, alignment invariants,
exhaustion, double-free rejection, tier bookkeeping, FreeList compat."""

import numpy as np
import pytest

from repro.pool import BuddyAllocator, TwoLevelTable


def test_constructor_validation():
    with pytest.raises(ValueError):
        BuddyAllocator(32, 3)  # not a power of two
    with pytest.raises(ValueError):
        BuddyAllocator(30, 8)  # n_slots not divisible by huge


def test_alloc_splits_down_and_free_coalesces_up():
    b = BuddyAllocator(16, 8)
    s = b.alloc(0)
    assert s == 0
    # one small alloc fragments exactly one huge block: frees 1+2+4 remain
    assert len(b) == 15
    assert b.check()
    b.free(s, 0)
    assert len(b) == 16
    # fully coalesced again: both huge runs allocatable
    assert b.take_run() == 0 and b.take_run() == 8 and b.take_run() is None
    assert b.check()


def test_alignment_invariant_all_orders():
    b = BuddyAllocator(32, 8)
    starts = [b.alloc(o) for o in (0, 1, 2, 3, 0, 1)]
    for start, o in zip(starts, (0, 1, 2, 3, 0, 1)):
        assert start % (1 << o) == 0, (start, o)
    assert b.check()


def test_exhaustion_returns_none_without_mutation():
    b = BuddyAllocator(8, 8)
    assert b.take_run() == 0
    assert b.take_run() is None
    assert b.take(1) is None and len(b) == 0
    b.free_run(0)
    got = b.take(8)
    assert sorted(got.tolist()) == list(range(8))
    assert b.take(1) is None
    b.put(got)
    assert len(b) == 8 and b.check()


def test_double_free_rejected():
    b = BuddyAllocator(16, 8)
    s = b.alloc(0)
    b.free(s, 0)
    with pytest.raises(ValueError):
        b.free(s, 0)
    run = b.take_run()
    b.free_run(run)
    with pytest.raises(ValueError):
        b.free_run(run)
    with pytest.raises(ValueError):
        b.free(5, 0)  # never allocated
    assert b.check()


def test_wrong_order_free_rejected():
    b = BuddyAllocator(16, 8)
    run = b.take_run()
    with pytest.raises(ValueError):
        b.free(run, 0)  # it is a huge allocation, not a small one
    b.free_run(run)
    assert b.check()


def test_fragmentation_blocks_runs_but_not_smalls():
    b = BuddyAllocator(16, 8)
    smalls = b.take(16)
    # free every other slot: 8 free slots but no contiguous aligned run
    b.put(smalls[::2])
    assert len(b) == 8
    assert b.take_run() is None
    assert b.take(8) is not None
    assert b.check()


def test_split_and_merge_allocated_roundtrip():
    b = BuddyAllocator(16, 8)
    run = b.take_run()
    b.split_allocated(run)  # demotion: G live smalls, bytes unmoved
    assert b.check()
    for i in range(3):
        b.free(run + i, 0)  # some members migrate away individually
    assert len(b) == 8 + 3  # the untouched second run + the freed members
    with pytest.raises(ValueError):
        b.merge_allocated(run)  # not fully live small anymore
    b.reserve(range(run, run + 3))
    b.merge_allocated(run)  # adoption: back to one live huge block
    b.free_run(run)
    assert len(b) == 16 and b.check()
    with pytest.raises(ValueError):
        b.split_allocated(run)  # nothing live there


def test_merge_allocated_requires_alignment():
    b = BuddyAllocator(16, 8)
    b.reserve(range(4, 12))  # contiguous but crossing the buddy boundary
    with pytest.raises(ValueError):
        b.merge_allocated(4)
    assert b.check()


def test_reserve_carves_exact_slots():
    b = BuddyAllocator(16, 4)
    b.reserve([0, 5, 6, 11])
    assert len(b) == 12
    assert sorted(set(range(16)) - set(b)) == [0, 5, 6, 11]
    with pytest.raises(ValueError):
        b.reserve([5])  # already live
    assert b.check()


def test_freelist_compat_shims():
    b = BuddyAllocator(8, 4)
    assert len(b) == 8
    s = b.popleft()
    assert s == 0  # lowest-address fit
    b.append(s)
    b.extend([])
    got = b.take(3)
    assert got is not None and len(b) == 5
    b.put(got)
    assert sorted(b) == list(range(8))
    bb = BuddyAllocator(4, 4)
    bb.reserve(range(4))
    with pytest.raises(IndexError):
        bb.popleft()


def test_two_level_table_invariants():
    t = TwoLevelTable(16, 4)
    assert t.n_groups == 4
    assert t.members(1).tolist() == [4, 5, 6, 7]
    assert not t.is_huge([0, 5, 9]).any()
    t.promote(1, region=0, start=8)
    assert t.is_huge([3, 4, 7, 8]).tolist() == [False, True, True, False]
    assert t.huge_groups().tolist() == [1]
    with pytest.raises(ValueError):
        t.promote(1, 0, 8)  # already huge
    with pytest.raises(ValueError):
        t.promote(2, 0, 9)  # misaligned start
    flat = np.zeros((16, 2), np.int32)
    flat[:, 1] = np.arange(16)
    flat[t.members(1), 1] = 8 + np.arange(4)
    assert t.check_consistent(flat)
    flat[5, 1] = 0  # member off its run
    with pytest.raises(AssertionError):
        t.check_consistent(flat)
    t.relocate(1, region=1, start=4)
    assert t.huge_loc[1].tolist() == [1, 4]
    t.demote(1)
    with pytest.raises(ValueError):
        t.demote(1)
    with pytest.raises(ValueError):
        t.relocate(1, 0, 0)
