"""Roofline machinery tests: the analytic accountant calibrated against XLA
cost analysis (on a scan-free probe), HLO collective parsing, and trip-count
scaling."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.configs.smoke import reduce
from repro.roofline import flops as fl
from repro.roofline import hlo as H
from repro.roofline import model as roof
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig, init_train_state, train_step


def test_accountant_calibrates_against_xla_cost_analysis():
    """On a single-layer, single-microbatch, unchunked config every loop has
    trip count 1, so XLA's per-body costs ARE the totals — the analytic
    accountant must agree with them (this is what justifies using it for the
    scanned 96-layer cells where cost_analysis undercounts)."""
    base = reduce(get_config("granite_3_2b"))
    cfg = dataclasses.replace(
        base,
        n_layers=1,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        head_dim=64,
        d_ff=1024,
        vocab_size=512,
        attn_chunk=4096,
    )
    seq, batch = 128, 4
    tcfg = TrainConfig(n_micro=1, optimizer=OptimizerConfig())
    state = jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg, tcfg))
    batch_struct = {
        "inputs": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    compiled = (
        jax.jit(lambda s, b: train_step(s, b, cfg, tcfg))
        .lower(state, batch_struct)
        .compile()
    )
    xla_flops = compiled.cost_analysis()["flops"]

    # analytic: reuse the per-block accountant with this cell's shapes
    lw = fl._block_fwd_flops_per_token(cfg, "attn", seq / 2)
    head = 2 * cfg.d_model * cfg.vocab_size
    n_tokens = batch * seq
    analytic = 4 * lw * n_tokens + 3 * head * n_tokens
    ratio = analytic / xla_flops
    assert 0.6 < ratio < 1.6, f"accountant mis-calibrated: {ratio=}"


def test_parse_collectives_shapes_and_factors():
    text = """
ENTRY %main (p0: f32[16,512]) -> f32[16,512] {
  %ag = f32[256,512]{1,0} all-gather(f32[16,512]{1,0} %p0), replica_groups=[1,16]<=[16], dimensions={0}
  %ar = bf16[16,512]{1,0} all-reduce(bf16[16,512]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %y), source_target_pairs={{0,1}}
}
"""
    ops = H.parse_collectives(text)
    assert len(ops) == 3
    ag, ar, cp = ops
    assert ag.kind == "all-gather" and ag.group_size == 16
    assert ag.result_bytes == 256 * 512 * 4
    assert ag.wire_bytes == int(ag.result_bytes * 15 / 16)
    assert ar.group_size == 4 and ar.wire_bytes == int(16 * 512 * 2 * 2 * 3 / 4)
    assert cp.wire_bytes == 4 * 4 * 4


def test_trip_count_scaling_synthetic():
    text = """HloModule test

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar.1 = f32[64]{0} all-reduce(f32[64]{0} %gte), replica_groups={{0,1}}, to_apply=%add
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(40)
  %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main (p0: f32[64]) -> f32[64] {
  %ar.0 = f32[64]{0} all-reduce(f32[64]{0} %p0), replica_groups={{0,1}}, to_apply=%add
  %w = (s32[], f32[64]) while((s32[], f32[64]) %t), condition=%cond.1, body=%body.1
}
"""
    scaled = H.scaled_wire_bytes(text)
    one_ar = 64 * 4  # x factor 2*(2-1)/2 = 1
    assert scaled["wire_bytes_raw"] == 2 * one_ar
    assert scaled["wire_bytes_scaled"] == 41 * one_ar  # entry x1 + body x40
    mult = H.computation_multiplicities(text)
    assert mult["body.1"] == 40


def test_trip_scaling_on_real_scan_program():
    """Compile a scanned program on a 2-device mesh subprocess-free check:
    single device has no collectives, so verify multiplicities only."""

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None

        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    txt = jax.jit(f).lower(jnp.ones((8, 8))).compile().as_text()
    mult = H.computation_multiplicities(txt)
    assert any(abs(m - 7.0) < 1e-6 for m in mult.values()), mult


def test_roofline_terms_and_dominance():
    art = {
        "flops_per_device": 197e12,  # exactly 1 s of compute
        "bytes_per_device": 819e9 * 2,  # 2 s of HBM
        "wire_bytes_per_device": 50e9 * 0.5,
        "model_flops": 197e12 * 256 * 0.5,
        "n_chips": 256,
    }
    t = roof.terms_from_artifact(art)
    assert t.dominant == "memory"
    assert abs(t.step_time_s - 2.0) < 1e-9
    assert abs(t.roofline_fraction - 0.25) < 1e-9


def test_hbm_accountant_itemization():
    cfg = get_config("granite_3_2b")
    c = fl.step_cost(cfg, "train_4k", 256)
    assert c.total_flops > c.fwd_flops > 0
    d = c.detail
    assert d["total"] == sum(v for k, v in d.items() if k != "total")
    # params dominate optimizer traffic for small models at batch 256
    assert d["weights"] > 0 and d["optimizer"] > 0
    c2 = fl.step_cost(cfg, "decode_32k", 256)
    assert c2.detail["cache_read"] > 0
