"""Pipeline-refactor regression tests.

Four families:
  * re-export shims — the pre-pipeline homes (``repro.core.driver``) keep
    exporting ``LeapConfig``/``MigrationStats``/``FreeList``/
    ``RequestState`` (and the same objects as the new modules);
  * scheduler policies — the SchedulerPolicy seam stamps admission tickets
    that flow through the shared dispatch/verdict stages;
  * cancel racing a relay's second hop — ``cancel_request()`` landing while
    first-hop commits have re-enqueued second hops must drop them
    slot-leak-free with exact accounting (PR-4 behavior, now pinned);
  * priority across stages — a high-priority request submitted after a
    low-priority one has entered the pipeline still overtakes it.
"""

import numpy as np

from repro.core import (
    LeapConfig,
    MigrationDriver,
    PoolConfig,
    init_state,
)
from repro.core.pipeline import (
    AdmissionTicket,
    LeapScheduler,
    SamplingScheduler,
    SchedulerPolicy,
    SyncScheduler,
    make_scheduler,
)
from repro.topology import NumaTopology


def make_driver(topo, n_regions, n_blocks, slots=None, leap=None, **kw):
    cfg = PoolConfig(
        n_regions, slots or max(n_blocks + 8, 32), (1, 16), topology=topo
    )
    state = init_state(cfg, n_blocks, np.zeros(n_blocks, np.int32))
    return MigrationDriver(state, cfg, leap or LeapConfig(), **kw)


# -- re-export shims ---------------------------------------------------------


def test_driver_module_reexports_pre_pipeline_names():
    from repro.core import config, queues, stats
    from repro.core import driver as drv_mod

    assert drv_mod.LeapConfig is config.LeapConfig
    assert drv_mod.MigrationStats is stats.MigrationStats
    assert drv_mod.RequestState is stats.RequestState
    assert drv_mod.FreeList is queues.FreeList
    assert drv_mod.AreaQueue is queues.AreaQueue
    # legacy private spellings still resolve
    assert drv_mod._AreaQueue is queues.AreaQueue
    assert drv_mod._CommitBatch is queues.CommitBatch


def test_core_driver_import_statement_keeps_working():
    # the literal import the acceptance criteria pins
    from repro.core.driver import FreeList, LeapConfig, MigrationStats  # noqa: F401


# -- scheduler policies ------------------------------------------------------


def test_make_scheduler_resolves_names_and_instances():
    assert isinstance(make_scheduler(None), LeapScheduler)
    assert isinstance(make_scheduler("leap"), LeapScheduler)
    assert isinstance(make_scheduler("sync"), SyncScheduler)
    sampling = make_scheduler("sampling", n_blocks=8)
    assert isinstance(sampling, SamplingScheduler)
    assert make_scheduler(sampling) is sampling
    for policy in (LeapScheduler(), SyncScheduler(), sampling):
        assert isinstance(policy, SchedulerPolicy)
    try:
        make_scheduler("bogus")
    except ValueError as e:
        assert "bogus" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected ValueError")


def test_sync_scheduler_driver_forces_in_one_drain():
    drv = make_driver(None, 2, 8, scheduler="sync")
    sess = drv.default_session()
    h = sess.leap(np.arange(8), 1)
    assert h.wait(10)
    p = h.progress()
    assert p.forced == 8 and p.committed == 0  # escalated, no copy epochs
    assert (drv.host_placement() == 1).all() and drv.verify_mirror()


def test_per_request_ticket_overrides_driver_policy():
    drv = make_driver(None, 2, 8)  # default leap policy
    sess = drv.default_session()
    h = sess.leap(np.arange(4), 1, ticket=AdmissionTicket(escalate=True))
    assert h.wait(10)
    assert h.progress().forced == 4
    h2 = sess.leap(np.asarray([4, 5]), 1)  # policy default: reliable epochs
    assert h2.wait(100)
    assert h2.progress().committed == 2 and h2.progress().forced == 0


def test_fresh_alloc_ticket_zeroes_destination_before_copy():
    import jax.numpy as jnp

    from repro.core import leap_read, leap_write

    cfg = PoolConfig(2, 16, (4,))
    state = init_state(cfg, 4, np.zeros(4, np.int32))
    data = np.arange(16, dtype=np.float32).reshape(4, 4) + 1.0
    state = leap_write(state, jnp.arange(4), jnp.asarray(data))
    drv = MigrationDriver(state, cfg)
    h = drv.default_session().leap(
        np.arange(4), 1, ticket=AdmissionTicket(fresh_alloc=True)
    )
    assert h.wait(100) and drv.verify_mirror()
    # payload survives the zero pass (zero lands before the copy)
    np.testing.assert_array_equal(
        np.asarray(leap_read(drv.state, jnp.arange(4))), data
    )
    assert drv.stats.blocks_migrated == 4


def test_drain_region_sync_scheduler_escalates_but_skips_nothing():
    from repro.distributed.fault import drain_region

    drv = make_driver(None, 3, 12, slots=16)
    sess = drv.default_session()
    n = drain_region(drv, 0, scheduler="sync")
    assert n == 12
    assert sess.drain()
    assert (drv.host_placement() != 0).all() and drv.verify_mirror()
    # the sync policy's escalation applied (atomic forces, no copy epochs)...
    assert drv.stats.blocks_forced == 12 and drv.stats.blocks_migrated == 0
    # ...but its EBUSY skip did not: every block left the dying region
    assert drv.stats.blocks_requested == 12


def test_same_tick_mixed_force_batches_preserve_payloads():
    # Regression: a batched (non-fresh) escalation frees its source slots in
    # the same tick that an opposite-direction fresh escalation opens.  The
    # quarantine must keep those slots out of the fresh area's hands until
    # the force batch has been dispatched — otherwise its zero/force pass
    # lands on slots the batched force still has to read.
    import jax.numpy as jnp

    from repro.core import leap_read, leap_write

    cfg = PoolConfig(2, 16, (4,))
    state = init_state(cfg, 8, np.asarray([0, 0, 0, 0, 1, 1, 1, 1], np.int32))
    data = np.arange(32, dtype=np.float32).reshape(8, 4) + 1.0
    state = leap_write(state, jnp.arange(8), jnp.asarray(data))
    drv = MigrationDriver(state, cfg)
    sess = drv.default_session()
    # both submitted before any tick: both open (and force) in ONE tick
    a = sess.leap(np.arange(4), 1, ticket=AdmissionTicket(escalate=True))
    b = sess.leap(
        np.arange(4, 8), 0,
        ticket=AdmissionTicket(escalate=True, fresh_alloc=True),
    )
    assert a.wait(100) and b.wait(100)
    assert drv.verify_mirror()
    np.testing.assert_array_equal(
        np.asarray(leap_read(drv.state, jnp.arange(8))), data
    )


def test_escalated_submit_keeps_huge_groups_already_at_destination():
    cfg = PoolConfig(2, 32, (4,), huge_factor=4)
    state = init_state(cfg, 16, np.zeros(16, np.int32))
    drv = MigrationDriver(state, cfg)
    assert drv.adopt_huge(np.arange(4)) == 4
    # a no-op escalated request (everything already home) must not split
    # healthy huge mappings
    req = drv.submit(np.arange(16), 0, ticket=AdmissionTicket(escalate=True))
    assert req.requested == 0 and req.done
    assert drv.stats.demotions == 0 and drv.verify_tiers()
    assert drv.tiers.tier.sum() == 4  # all four groups still huge


# -- cancel racing a relay's second hop --------------------------------------


def _tick_until_second_hop_queued(drv, sess, handle, relay_regions, max_ticks=500):
    """Advance until some blocks of ``handle`` sit at a relay region with
    their (queued, unopened) second hop pending; returns those block ids."""
    for _ in range(max_ticks):
        sess.tick()
        sess.poll(block=True)
        placement = drv.host_placement()
        parked = np.nonzero(np.isin(placement, relay_regions))[0]
        if len(parked) and not handle.done:
            return parked
    raise AssertionError("second hop never became observable")


def test_cancel_while_relay_second_hop_is_queued():
    # quad socket with the 0->1 link congested: traffic 0->1 relays via 2/3
    topo = NumaTopology.quad_socket().congested(0, 1, 16)
    drv = make_driver(topo, 4, 48, leap=LeapConfig(budget_blocks_per_tick=8))
    sess = drv.default_session()
    h = sess.leap(np.arange(48), 1)
    assert drv.stats.multi_hop_areas > 0  # routing really planned a relay
    parked = _tick_until_second_hop_queued(drv, sess, h, relay_regions=(2, 3))
    dropped = h.cancel()
    assert dropped > 0  # the queued second hop (plus any queued first hops)
    assert h.wait(500)
    p = h.progress()
    # exact accounting across both hops: every block terminal exactly once
    assert p.committed + p.forced + p.cancelled == p.requested == 48
    assert p.cancelled >= len(parked)  # the parked blocks never re-departed
    assert drv.done and drv.verify_mirror()
    # parked blocks stay at the relay region, not the final destination...
    assert np.isin(drv.host_placement()[parked], (2, 3)).all()
    # ...and are re-submittable immediately (their open marks were cleared,
    # no destination slots leaked)
    assert not drv.in_migration(parked).any()
    h2 = sess.leap(parked, 1)
    assert h2.requested == len(parked) and h2.wait(1000)
    assert (drv.host_placement()[parked] == 1).all() and drv.verify_mirror()


def test_cancel_after_full_relay_delivery_is_a_noop():
    topo = NumaTopology.quad_socket().congested(0, 1, 16)
    drv = make_driver(topo, 4, 16)
    sess = drv.default_session()
    h = sess.leap(np.arange(16), 1)
    assert h.wait(1000)
    assert h.cancel() == 0  # terminal: nothing to drop
    p = h.progress()
    assert p.committed == 16 and p.cancelled == 0


# -- priority across pipeline stages -----------------------------------------


def test_high_priority_overtakes_low_priority_mid_pipeline():
    # Low-priority request enters the pipeline first and gets a head start
    # (one tick: areas open/copy).  A high-priority request submitted AFTER
    # must still finish strictly earlier: the admission stage queues it
    # ahead, and dispatch drains its areas before opening more low ones.
    drv = make_driver(
        None, 2, 64,
        slots=80,
        leap=LeapConfig(initial_area_blocks=8, budget_blocks_per_tick=8),
    )
    sess = drv.default_session()
    order = []
    low = sess.leap(
        np.arange(48), 1, priority=0, on_done=lambda h: order.append("low")
    )
    sess.tick()  # low-priority areas are now mid-pipeline (active/copying)
    high = sess.leap(
        np.arange(48, 64), 1, priority=5, on_done=lambda h: order.append("high")
    )
    ticks_high = None
    for t in range(2000):
        sess.tick()
        sess.poll(block=True)
        if high.done and ticks_high is None:
            ticks_high = t
        if low.done and high.done:
            break
    assert high.done and low.done
    assert order == ["high", "low"]  # completion order, not submit order
    # high finished while low still had work left: no priority inversion
    assert ticks_high is not None
    assert low.progress().committed + low.progress().forced == 48


def test_priority_preserved_across_split_and_requeue():
    # A dirtied high-priority area splits in the verdict stage; its fragments
    # must keep the priority and drain before the low request's still-QUEUED
    # areas (in-flight low epochs may finish — priority governs the queue,
    # it does not preempt open epochs).
    import jax.numpy as jnp

    drv = make_driver(
        None, 2, 64,
        slots=80,
        leap=LeapConfig(initial_area_blocks=16, budget_blocks_per_tick=16),
    )
    sess = drv.default_session()
    vals = jnp.zeros((4, 1, 16), np.float32)
    high = sess.leap(np.arange(16), 1, priority=5)
    low = sess.leap(np.arange(16, 64), 1, priority=0)  # 3 areas, mostly queued
    # dirty part of the high request mid-epoch so it splits and requeues
    sess.tick()
    drv.write(jnp.asarray(np.arange(4, dtype=np.int32)), vals)
    done_order = []
    high.on_done(lambda h: done_order.append("high"))
    low.on_done(lambda h: done_order.append("low"))
    for _ in range(2000):
        if high.done and low.done:
            break
        sess.tick()
        sess.poll(block=True)
    assert high.done and low.done and drv.verify_mirror()
    assert done_order[0] == "high"
    assert high.progress().committed == 16  # split fragments re-committed clean
