"""The CI perf-regression gate (scripts/bench_compare.py) must pass identical
results, fail an injected 2x regression (on both wall clock and key derived
metrics), catch dropped rows and failed suites, and stay calm under a
uniform machine-speed shift (median calibration)."""

import importlib.util
import json
import os

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "bench_compare.py"),
)
bench_compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_compare)


def _write_suite(dirpath, suite, rows, ok=True):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, f"BENCH_{suite}.json"), "w") as f:
        json.dump(
            {
                "suite": suite,
                "ok": ok,
                "elapsed_s": 1.0,
                "rows": [
                    {"name": n, "us_per_call": us, "derived": d}
                    for n, us, d in rows
                ],
            },
            f,
        )


def _gate(tmp_path, extra_args=()):
    return bench_compare.main(
        [
            "--results",
            str(tmp_path / "cur"),
            "--baselines",
            str(tmp_path / "base"),
            *extra_args,
        ]
    )


ROWS = [
    ("a/x", 10_000.0, ""),
    ("a/y", 20_000.0, ""),
    ("a/z", 5_000.0, ""),
    ("a/w", 40_000.0, ""),
]


def test_parse_derived():
    parsed = bench_compare.parse_derived(
        "modeled=33.0;ticks=3;speedup=x4.71;slowdown=4%;outputs=identical"
    )
    assert parsed == {"modeled": 33.0, "ticks": 3.0, "speedup": 4.71, "slowdown": 4.0}


def test_identical_results_pass(tmp_path):
    _write_suite(tmp_path / "base", "s1", ROWS)
    _write_suite(tmp_path / "cur", "s1", ROWS)
    assert _gate(tmp_path) == 0


def test_injected_2x_wall_regression_fails(tmp_path):
    # on quiet hardware the wall gate can be tightened to catch a 2x; the
    # default threshold is catastrophe-only (shared-runner noise exceeds 2x)
    _write_suite(tmp_path / "base", "s1", ROWS)
    slow = [(n, us * (2.0 if n == "a/y" else 1.0), d) for n, us, d in ROWS]
    _write_suite(tmp_path / "cur", "s1", slow)
    assert _gate(tmp_path, ["--wall-threshold", "0.9"]) == 1


def test_injected_4x_wall_catastrophe_fails_by_default(tmp_path):
    _write_suite(tmp_path / "base", "s1", ROWS)
    slow = [(n, us * (4.0 if n == "a/y" else 1.0), d) for n, us, d in ROWS]
    _write_suite(tmp_path / "cur", "s1", slow)
    assert _gate(tmp_path) == 1


def test_injected_2x_key_metric_regression_fails(tmp_path):
    # a 2x regression of a derived key metric (modeled completion time)
    # fails the tight 25% threshold even though wall clock is identical
    base = [("a/x", 10_000.0, "modeled=7.0;ticks=7")] + ROWS[1:]
    cur = [("a/x", 10_000.0, "modeled=14.0;ticks=7")] + ROWS[1:]
    _write_suite(tmp_path / "base", "s1", base)
    _write_suite(tmp_path / "cur", "s1", cur)
    assert _gate(tmp_path) == 1


def test_speedup_drop_fails_and_gain_passes(tmp_path):
    base = [("a/x", 10_000.0, "speedup=x2.00")] + ROWS[1:]
    _write_suite(tmp_path / "base", "s1", base)
    _write_suite(
        tmp_path / "cur", "s1", [("a/x", 10_000.0, "speedup=x1.20")] + ROWS[1:]
    )
    assert _gate(tmp_path) == 1
    _write_suite(
        tmp_path / "cur", "s1", [("a/x", 10_000.0, "speedup=x3.00")] + ROWS[1:]
    )
    assert _gate(tmp_path) == 0


def test_noisy_fast_ratio_metrics_are_not_gated(tmp_path):
    # speedup_warm is a ~20ms within-run wall ratio: explicitly exempt
    base = [("a/x", 10_000.0, "speedup_warm=x2.00")] + ROWS[1:]
    cur = [("a/x", 10_000.0, "speedup_warm=x0.50")] + ROWS[1:]
    _write_suite(tmp_path / "base", "s1", base)
    _write_suite(tmp_path / "cur", "s1", cur)
    assert _gate(tmp_path) == 0


def test_small_slowdown_shift_within_slack_passes(tmp_path):
    # slowdown is a measured decode ratio that can jitter (and go negative):
    # small point shifts pass, a genuine jump past the point slack fails
    base = [("a/x", 10_000.0, "slowdown=-3%")] + ROWS[1:]
    _write_suite(tmp_path / "base", "s1", base)
    _write_suite(tmp_path / "cur", "s1", [("a/x", 10_000.0, "slowdown=9%")] + ROWS[1:])
    assert _gate(tmp_path) == 0
    _write_suite(tmp_path / "cur", "s1", [("a/x", 10_000.0, "slowdown=30%")] + ROWS[1:])
    assert _gate(tmp_path) == 1


def test_mem_overhead_is_gated_tightly(tmp_path):
    # deterministic accounting: small slack only
    base = [("a/x", 10_000.0, "mem_overhead=2.3%")] + ROWS[1:]
    _write_suite(tmp_path / "base", "s1", base)
    _write_suite(
        tmp_path / "cur", "s1", [("a/x", 10_000.0, "mem_overhead=3.0%")] + ROWS[1:]
    )
    assert _gate(tmp_path) == 0
    _write_suite(
        tmp_path / "cur", "s1", [("a/x", 10_000.0, "mem_overhead=10.0%")] + ROWS[1:]
    )
    assert _gate(tmp_path) == 1


def test_uniform_machine_shift_is_calibrated_away(tmp_path):
    # everything 1.6x slower (a slower CI runner): median calibration absorbs it
    _write_suite(tmp_path / "base", "s1", ROWS)
    _write_suite(tmp_path / "cur", "s1", [(n, us * 1.6, d) for n, us, d in ROWS])
    assert _gate(tmp_path) == 0


def test_regression_on_shifted_machine_still_fails(tmp_path):
    _write_suite(tmp_path / "base", "s1", ROWS)
    cur = [(n, us * 1.6 * (4.0 if n == "a/y" else 1.0), d) for n, us, d in ROWS]
    _write_suite(tmp_path / "cur", "s1", cur)
    assert _gate(tmp_path) == 1


def test_dispatch_and_jit_key_metrics_are_gated(tmp_path):
    # control-path regressions are deterministic derived metrics: a doubled
    # dispatches-per-tick or a warm-compile storm fails without wall noise
    base = [("a/x", 10_000.0, "disp_per_tick=2.00;jit_misses_warm=0")] + ROWS[1:]
    _write_suite(tmp_path / "base", "s1", base)
    _write_suite(
        tmp_path / "cur",
        "s1",
        [("a/x", 10_000.0, "disp_per_tick=4.00;jit_misses_warm=0")] + ROWS[1:],
    )
    assert _gate(tmp_path) == 1
    _write_suite(
        tmp_path / "cur",
        "s1",
        [("a/x", 10_000.0, "disp_per_tick=2.00;jit_misses_warm=7")] + ROWS[1:],
    )
    assert _gate(tmp_path) == 1
    _write_suite(
        tmp_path / "cur",
        "s1",
        [("a/x", 10_000.0, "disp_per_tick=2.00;jit_misses_warm=1")] + ROWS[1:],
    )
    assert _gate(tmp_path) == 0


def test_dropped_row_fails(tmp_path):
    _write_suite(tmp_path / "base", "s1", ROWS)
    _write_suite(tmp_path / "cur", "s1", ROWS[:-1])
    assert _gate(tmp_path) == 1


def test_failed_suite_fails(tmp_path):
    _write_suite(tmp_path / "base", "s1", ROWS)
    _write_suite(tmp_path / "cur", "s1", ROWS, ok=False)
    assert _gate(tmp_path) == 1


def test_baselined_suite_missing_from_results_fails(tmp_path):
    # a dropped CI step (no BENCH json produced at all) is a coverage
    # regression, exactly like a dropped row
    _write_suite(tmp_path / "base", "s1", ROWS)
    _write_suite(tmp_path / "base", "s2", ROWS)
    _write_suite(tmp_path / "cur", "s1", ROWS)
    assert _gate(tmp_path) == 1


def test_new_suite_and_new_rows_pass_ungated(tmp_path):
    _write_suite(tmp_path / "base", "s1", ROWS)
    _write_suite(tmp_path / "cur", "s1", ROWS + [("a/new", 1e6, "")])
    _write_suite(tmp_path / "cur", "s2", [("b/x", 1e6, "")])
    assert _gate(tmp_path) == 0


def test_modeled_rows_do_not_poison_wall_calibration(tmp_path):
    # modeled rows carry machine-independent us_per_call (ratio pinned at
    # 1.0); on a 3x faster host they must neither flag themselves nor skew
    # the calibration median the genuine wall rows rely on
    modeled = [(f"m/{i}", 7_000.0, "modeled=7.0") for i in range(6)]
    _write_suite(tmp_path / "base", "s1", ROWS + modeled)
    cur = [(n, us / 3.2, d) for n, us, d in ROWS] + modeled
    _write_suite(tmp_path / "cur", "s1", cur)
    assert _gate(tmp_path) == 0


def test_tiny_rows_are_wall_noise_exempt(tmp_path):
    rows = ROWS + [("a/tiny", 5.0, "")]
    _write_suite(tmp_path / "base", "s1", rows)
    cur = [(n, us * (10.0 if n == "a/tiny" else 1.0), d) for n, us, d in rows]
    _write_suite(tmp_path / "cur", "s1", cur)
    assert _gate(tmp_path) == 0


def test_write_baselines_seeds_then_passes(tmp_path):
    _write_suite(tmp_path / "cur", "s1", ROWS)
    assert _gate(tmp_path, ["--write-baselines"]) == 0
    assert (tmp_path / "base" / "BENCH_s1.json").exists()
    assert _gate(tmp_path) == 0


def test_empty_results_dir_is_an_error(tmp_path):
    os.makedirs(tmp_path / "cur", exist_ok=True)
    os.makedirs(tmp_path / "base", exist_ok=True)
    assert _gate(tmp_path) == 2
