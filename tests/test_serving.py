"""Serving engine tests: paged decode correctness vs the contiguous path,
and decode equivalence under live KV-block migration (the paper's
correctness property on the serving integration)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.smoke import reduce
from repro.core import LeapConfig
from repro.models import lm
from repro.serving.engine import PagedConfig, PagedEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduce(get_config("granite_3_2b")), n_layers=2)
    params = lm.init_params(jax.random.key(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    pcfg = PagedConfig(block_tokens=4, max_blocks_per_seq=16,
                       n_regions=2, slots_per_region=64, **kw)
    return PagedEngine(cfg, params, pcfg)


def _contiguous_decode(cfg, params, prompt, n_steps):
    max_len = len(prompt) + n_steps
    logits, cache = jax.jit(lambda p, t: lm.prefill(p, t, cfg, max_len))(
        params, jnp.asarray(prompt)[None]
    )
    toks = [int(jnp.argmax(logits, -1)[0])]
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    pos = len(prompt)
    for i in range(n_steps - 1):
        logits, cache = step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray(pos, jnp.int32),
        )
        toks.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return toks


def test_paged_matches_contiguous(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=9)  # crosses block boundary
    want = _contiguous_decode(cfg, params, prompt, 6)
    eng = _engine(cfg, params)
    sid = eng.admit(prompt)
    got = [eng.seqs[sid].tokens[-1]]  # first token comes from prefill logits
    for _ in range(5):
        got.extend(eng.decode([sid]))
    assert got == want, (got, want)


def test_paged_batched_multiple_sequences(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 12)]
    want = [_contiguous_decode(cfg, params, p, 4) for p in prompts]
    eng = _engine(cfg, params)
    sids = [eng.admit(p, region=i % 2) for i, p in enumerate(prompts)]
    got = [[eng.seqs[s].tokens[-1]] for s in sids]
    for _ in range(3):
        outs = eng.decode(sids)
        for i, t in enumerate(outs):
            got[i].append(t)
    assert got == want


def test_decode_correct_under_live_migration(setup):
    """Decode while the sequence's KV pages leap-migrate between regions:
    outputs must equal a no-migration run (reads through the table; appends
    dirty in-flight pages; retries preserve every append)."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=10)
    n_steps = 10
    want = _contiguous_decode(cfg, params, prompt, n_steps)

    eng = _engine(cfg, params, leap=LeapConfig(
        initial_area_blocks=2, chunk_blocks=1, budget_blocks_per_tick=1,
        max_attempts_before_force=3,
    ))
    sid = eng.admit(prompt)
    eng.rebalance(sid, dst_region=1)  # start live migration
    got = [eng.seqs[sid].tokens[-1]]
    for i in range(n_steps - 1):
        eng.tick()  # migration slice
        got.extend(eng.decode([sid]))  # concurrent decode (appends!)
    assert eng.drain()
    # all pages ended up on region 1
    seq = eng.seqs[sid]
    assert all(
        int(r) == 1 for r in eng.facade.region_of(np.asarray(seq.block_ids))
    )
    assert got == want, (got, want)
    assert eng.driver.stats.blocks_migrated + eng.driver.stats.blocks_forced >= 3


def test_release_returns_blocks(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    free_before = sum(len(f) for f in eng._free_blocks)
    sid = eng.admit(np.arange(8) % cfg.vocab_size)
    assert sum(len(f) for f in eng._free_blocks) < free_before
    eng.release(sid)
    assert sum(len(f) for f in eng._free_blocks) == free_before


def test_paged_engine_moe_arch():
    """The paged engine also serves MoE stacks (dbrx family): decode through
    paged attention + expert FFN must match the contiguous path."""
    cfg = dataclasses.replace(reduce(get_config("dbrx_132b")), n_layers=2)
    params = lm.init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=7)
    want = _contiguous_decode(cfg, params, prompt, 4)
    eng = _engine(cfg, params)
    sid = eng.admit(prompt)
    got = [eng.seqs[sid].tokens[-1]]
    for _ in range(3):
        got.extend(eng.decode([sid]))
    assert got == want, (got, want)


def test_rebalance_returns_handle_and_engine_is_a_policy(setup):
    """rebalance() hands back a LeapHandle future, and the engine's own
    ``decide()`` (sequence affinity) drives the session — policy separated
    from mechanism."""
    from repro.api import HandleStatus

    cfg, params = setup
    eng = _engine(cfg, params)
    sid = eng.admit(np.arange(8) % cfg.vocab_size)
    n_pages = len(eng.seqs[sid].block_ids)
    h = eng.rebalance(sid, dst_region=1)
    assert h.tag == sid and h.requested == n_pages
    assert h.wait()
    assert h.status == HandleStatus.COMMITTED
    p = h.progress()
    assert p.committed + p.forced == p.requested == n_pages
    regions = eng.facade.region_of(np.asarray(eng.seqs[sid].block_ids))
    assert (np.asarray(regions) == 1).all()
    # once every page is home, the affinity policy proposes nothing
    assert eng.decide(eng.facade) == []
    # cancellation on the serving path leaks nothing
    h2 = eng.rebalance(sid, dst_region=0)
    h2.cancel()
    assert h2.done and eng.drain()
    assert eng.driver.verify_mirror()


def test_rebalance_latency_attribution(setup):
    """With telemetry on, the engine attributes per-sequence rebalance
    latency from the KV pool's recorder; off, both accessors degrade to
    None/disabled rather than erroring."""
    cfg, params = setup
    eng = _engine(cfg, params, leap=LeapConfig(telemetry=True))
    sid = eng.admit(np.arange(8) % cfg.vocab_size)
    assert eng.rebalance_latency(sid) is None  # never rebalanced yet
    h = eng.rebalance(sid, dst_region=1)
    assert h.wait()
    lat = eng.rebalance_latency(sid)
    assert lat is not None and lat.rid == h.request_id
    assert lat.outcome == "COMMITTED"
    assert lat.requested == len(eng.seqs[sid].block_ids)
    assert lat.ticks_total >= 0 and lat.wall_s >= 0
    view = eng.telemetry()
    assert view.enabled
    assert view.counters()["blocks_migrated"] == eng.driver.stats.blocks_migrated

    eng_off = _engine(cfg, params)  # telemetry defaults off
    sid2 = eng_off.admit(np.arange(8) % cfg.vocab_size)
    h2 = eng_off.rebalance(sid2, dst_region=1)
    assert h2.wait()
    assert not eng_off.telemetry().enabled
    assert eng_off.rebalance_latency(sid2) is None


# Hypothesis property test over arbitrary decode/tick/rebalance schedules:
# see test_property_serving.py (guarded by pytest.importorskip("hypothesis")).
