"""Sweep tests: paged flash-decode Pallas kernel (interpret) vs jnp oracle,
plus the log-sum-exp shard-combine identity used by sequence-sharded decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_attn import paged_decode_pallas

# (B, H, KVH, hd, BLK, MAXB)
CASES = [
    (2, 4, 2, 64, 8, 4),
    (1, 8, 1, 128, 16, 3),  # MQA
    (3, 6, 6, 64, 8, 2),  # MHA
    (2, 12, 4, 128, 8, 5),  # GQA g=3
]


def _setup(b, h, kvh, hd, blk, maxb, dtype, seed=0):
    rng = np.random.default_rng(seed)
    s = b * maxb + 4
    q = jnp.asarray(rng.normal(size=(b, h, hd)), dtype)
    kv_pool = jnp.asarray(rng.normal(size=(s, 2, blk, kvh, hd)), dtype)
    # unique slots per sequence (a real block table never double-maps)
    slots = rng.choice(s, size=(b, maxb), replace=False)
    tables = jnp.asarray(slots, jnp.int32)
    lens = jnp.asarray(rng.integers(1, maxb * blk + 1, size=(b,)), jnp.int32)
    return q, kv_pool, tables, lens


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_matches_oracle(case, dtype):
    b, h, kvh, hd, blk, maxb = case
    q, kv_pool, tables, lens = _setup(*case, dtype)
    g = h // kvh
    out, m, l = paged_decode_pallas(
        q.reshape(b, kvh, g, hd), kv_pool, tables, lens, interpret=True
    )
    want_out, want_m, want_l = ref.paged_decode_ref(q, kv_pool, tables, lens)
    np.testing.assert_allclose(
        np.asarray(out.reshape(b, h, hd), np.float32),
        np.asarray(want_out, np.float32),
        **_tol(dtype),
    )
    np.testing.assert_allclose(
        np.asarray(m.reshape(b, h)), np.asarray(want_m), **_tol(dtype)
    )
    np.testing.assert_allclose(
        np.asarray(l.reshape(b, h)), np.asarray(want_l), **_tol(dtype)
    )


def test_paged_decode_softcap():
    case = (2, 4, 2, 64, 8, 4)
    q, kv_pool, tables, lens = _setup(*case, jnp.float32, seed=7)
    b, h, kvh, hd, blk, maxb = case
    out, m, l = paged_decode_pallas(
        q.reshape(b, kvh, h // kvh, hd), kv_pool, tables, lens, softcap=20.0, interpret=True
    )
    want, _, _ = ref.paged_decode_ref(q, kv_pool, tables, lens, softcap=20.0)
    np.testing.assert_allclose(
        np.asarray(out.reshape(b, h, hd)), np.asarray(want), rtol=2e-5, atol=2e-5
    )
    # softcap must actually change the result
    plain, _, _ = ref.paged_decode_ref(q, kv_pool, tables, lens)
    assert not np.allclose(np.asarray(want), np.asarray(plain))


def test_paged_decode_single_token_sequences():
    b, h, kvh, hd, blk, maxb = 2, 4, 2, 64, 8, 4
    q, kv_pool, tables, _ = _setup(b, h, kvh, hd, blk, maxb, jnp.float32, seed=3)
    lens = jnp.ones((b,), jnp.int32)  # attention over exactly one token
    out, m, l = paged_decode_pallas(
        q.reshape(b, kvh, h // kvh, hd), kv_pool, tables, lens, interpret=True
    )
    want, _, _ = ref.paged_decode_ref(q, kv_pool, tables, lens)
    np.testing.assert_allclose(
        np.asarray(out.reshape(b, h, hd)), np.asarray(want), rtol=2e-5, atol=2e-5
    )
    # l must be exactly 1 (softmax over a single position)
    np.testing.assert_allclose(np.asarray(l), 1.0, rtol=1e-6)


def test_shard_combine_identity():
    """Splitting a sequence's blocks across P shards and LSE-combining the
    partials must equal unsharded attention (the sequence-sharded decode path)."""
    b, h, kvh, hd, blk, maxb = 2, 8, 2, 64, 8, 6
    q, kv_pool, tables, _ = _setup(b, h, kvh, hd, blk, maxb, jnp.float32, seed=9)
    lens = jnp.full((b,), maxb * blk, jnp.int32)
    full, _, _ = ref.paged_decode_ref(q, kv_pool, tables, lens)
    # shard the table into 2 halves of 3 blocks
    outs, ms, ls = [], [], []
    for p in range(2):
        tab = tables[:, p * 3 : (p + 1) * 3]
        ln = jnp.full((b,), 3 * blk, jnp.int32)
        o, m, l = ref.paged_decode_ref(q, kv_pool, tab, ln)
        outs.append(o), ms.append(m), ls.append(l)
    combined = ref.combine_partials(
        jnp.stack(outs), jnp.stack(ms), jnp.stack(ls)
    )
    np.testing.assert_allclose(np.asarray(combined), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_ops_paged_decode_wrapper():
    b, h, kvh, hd, blk, maxb = 2, 4, 2, 64, 8, 4
    q, kv_pool, tables, lens = _setup(b, h, kvh, hd, blk, maxb, jnp.float32, seed=5)
    # pad entries deliberately out of range: wrapper must sanitize them
    n_valid = (np.asarray(lens) + blk - 1) // blk
    tab = np.asarray(tables).copy()
    for i in range(b):
        tab[i, n_valid[i] :] = 10**6
    out_ref_impl = ops.paged_decode(
        q, kv_pool, jnp.asarray(tab), lens, kv_heads=kvh, impl="ref"
    )
    out_pallas = ops.paged_decode(
        q, kv_pool, jnp.asarray(tab), lens, kv_heads=kvh, impl="pallas_interpret"
    )
    np.testing.assert_allclose(
        np.asarray(out_pallas), np.asarray(out_ref_impl), rtol=2e-5, atol=2e-5
    )
