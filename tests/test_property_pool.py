"""Hypothesis property tests for the two-tier pool: random alloc/free/
promote/demote/migrate interleavings never corrupt the buddy free lists or
the two-level table, and every logical block stays readable (with the right
bytes) across promotion/demotion during active migration.

Kept importorskip-guarded like the other property suites so tier-1 collects
without the optional ``hypothesis`` dev dependency.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import LeapConfig, MigrationDriver, PoolConfig, init_state, leap_write
from repro.pool import BuddyAllocator


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_ops=st.integers(10, 80),
    huge=st.sampled_from([2, 4, 8]),
)
def test_property_buddy_random_ops_keep_invariants(seed, n_ops, huge):
    """Random alloc/free/split/merge traffic: the free list stays coherent
    (alignment, exact partition, full coalescing) and misuse always raises."""
    rng = np.random.default_rng(seed)
    n_slots = huge * int(rng.integers(2, 9))
    b = BuddyAllocator(n_slots, huge)
    live_small: list[int] = []
    live_huge: list[int] = []
    for _ in range(n_ops):
        op = rng.integers(0, 6)
        if op == 0:  # small alloc
            s = b.alloc(0)
            if s is not None:
                live_small.append(s)
        elif op == 1 and live_small:  # small free
            b.free(live_small.pop(int(rng.integers(len(live_small)))), 0)
        elif op == 2:  # huge alloc
            s = b.take_run()
            if s is not None:
                live_huge.append(s)
        elif op == 3 and live_huge:  # huge free
            b.free_run(live_huge.pop(int(rng.integers(len(live_huge)))))
        elif op == 4 and live_huge:  # demote
            s = live_huge.pop(int(rng.integers(len(live_huge))))
            b.split_allocated(s)
            live_small.extend(range(s, s + huge))
        elif op == 5:  # merge an aligned fully-live run if one exists
            starts = {s for s in live_small if s % huge == 0}
            runs = [
                s for s in starts
                if all(s + i in live_small for i in range(huge))
            ]
            if runs:
                s = runs[0]
                b.merge_allocated(s)
                live_small = [x for x in live_small if not s <= x < s + huge]
                live_huge.append(s)
        b.check()
    assert len(b) == n_slots - len(live_small) - huge * len(live_huge)
    # double frees always rejected, whatever the history
    if live_small:
        b.free(live_small[0], 0)
        with pytest.raises(ValueError):
            b.free(live_small[0], 0)
    b.check()


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    writes_per_tick=st.integers(0, 4),
    huge=st.sampled_from([2, 4]),
    demote_after=st.integers(1, 3),
)
def test_property_tiered_interleavings_preserve_contents(
    seed, writes_per_tick, huge, demote_after
):
    """Random migrate/promote/write/tick interleavings on a tiered pool:
    every block stays readable with exact contents, tier metadata stays
    consistent with the flat table, and the allocators never corrupt."""
    rng = np.random.default_rng(seed)
    n_groups, n_regions = 4, 2
    n_blocks = n_groups * huge
    cfg = PoolConfig(n_regions, n_blocks * 2, (4,), huge_factor=huge)
    state = init_state(cfg, n_blocks, np.zeros(n_blocks, np.int32))
    data = rng.normal(size=(n_blocks, 4)).astype(np.float32)
    state = leap_write(state, jnp.arange(n_blocks), jnp.asarray(data))
    drv = MigrationDriver(
        state,
        cfg,
        LeapConfig(
            initial_area_blocks=huge,
            budget_blocks_per_tick=huge,
            demote_after_attempts=demote_after,
            max_attempts_before_force=demote_after + 3,
        ),
    )
    drv.adopt_huge(rng.choice(n_groups, size=2, replace=False))
    expected = data.copy()
    for _ in range(40):
        op = rng.integers(0, 4)
        if op == 0:  # request migration of a random span
            ids = rng.choice(n_blocks, size=int(rng.integers(1, n_blocks)), replace=False)
            drv.request(ids, int(rng.integers(0, n_regions)))
        elif op == 1:  # try promoting a random group
            drv.promote_group(int(rng.integers(0, n_groups)))
        elif op == 2 and writes_per_tick:
            ids = rng.choice(n_blocks, size=writes_per_tick, replace=False)
            vals = rng.normal(size=(writes_per_tick, 4)).astype(np.float32)
            drv.write(jnp.asarray(ids.astype(np.int32)), jnp.asarray(vals))
            expected[ids] = vals
        else:
            drv.tick()
        # invariants hold mid-migration, across promotions and demotions
        assert drv.verify_tiers()
        np.testing.assert_array_equal(
            np.asarray(drv.read(jnp.arange(n_blocks))), expected
        )
    assert drv.drain()
    assert drv.verify_mirror() and drv.verify_tiers()
    np.testing.assert_array_equal(
        np.asarray(drv.read(jnp.arange(n_blocks))), expected
    )
    # slot conservation: live allocations exactly cover the logical blocks
    used = sum(
        cfg.slots_per_region - drv.free_slots(r) for r in range(cfg.n_regions)
    )
    assert used == n_blocks
