"""Tier-1 tests for the chaos harness: spec round-trip, deterministic
replay, seeded scenario smoke, the sabotage/catch loop (a deliberately
re-introduced bug must be caught with a replayable serialized repro), and
the drain_region idempotency regression (satellite of the same PR).

The generative Hypothesis exploration lives in test_property_chaos.py
(importorskip) so this file runs in the tier-1 suite without dev deps.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.chaos import (
    EVENT_KINDS,
    ChaosDriver,
    FaultEvent,
    InvariantChecker,
    InvariantViolation,
    ScenarioSpec,
    run_scenario,
    run_with_repro,
    sample_spec,
)
from repro.core import LeapConfig, MigrationDriver, PoolConfig, init_state, leap_write
from repro.distributed import fault

# The minimal deterministic scenario that trips the ``skip_quarantine``
# sabotage: a sync-policy exchange over a spread placement forces both
# directions in one tick with fresh-alloc zero fill, so the LIFO free list
# hands a just-freed (sabotage: unquarantined) source slot straight back
# out as a zero-filled destination before the force program has read it.
SABOTAGE_SPEC = ScenarioSpec(
    seed=0,
    ticks=4,
    n_regions=2,
    slots_per_region=16,
    n_blocks=8,
    block_elems=4,
    placement="spread",
    scheduler="sync",
    workload="exchange",
)


# -- spec round-trip ---------------------------------------------------------


def test_spec_json_roundtrip_with_faults():
    spec = ScenarioSpec(
        seed=7,
        ticks=12,
        n_regions=4,
        slots_per_region=16,
        n_blocks=8,
        topology="cxl_pooled",
        topology_args=(2, 2),
        workload="stream",
        faults=(
            FaultEvent("drain_region", tick=3, args={"region": 1}),
            FaultEvent("congest_link", args={"src": 0, "dst": 1, "factor": 4.0}),
            FaultEvent("cancel_storm", tick=5, args={"frac": 0.5}),
        ),
    )
    spec.validate()
    again = ScenarioSpec.from_json(spec.to_json())
    assert again == spec
    assert ScenarioSpec.from_dict(spec.to_dict()) == spec
    # the JSON form is plain data: nested fault events serialize as dicts
    raw = json.loads(spec.to_json())
    assert raw["faults"][0] == {
        "kind": "drain_region", "tick": 3, "args": {"region": 1}
    }


def test_spec_rejects_unknown_fields_and_bad_events():
    with pytest.raises(ValueError, match="warp_factor"):
        ScenarioSpec.from_dict({"seed": 1, "warp_factor": 9})
    with pytest.raises(ValueError):
        ScenarioSpec(faults=(FaultEvent("meteor_strike"),)).validate()
    with pytest.raises(ValueError):
        ScenarioSpec(n_blocks=99, slots_per_region=16).validate()
    with pytest.raises(ValueError):
        ScenarioSpec(topology="two_socket", n_regions=3).validate()


def test_sampled_specs_are_valid_and_deterministic():
    for seed in range(20):
        spec = sample_spec(seed)
        spec.validate()  # sampler only emits valid specs
        assert spec == sample_spec(seed)  # pure function of the seed
        assert all(ev.kind in EVENT_KINDS for ev in spec.faults)


# -- scenario runs -----------------------------------------------------------


def test_scenario_run_is_deterministic():
    spec = sample_spec(3)
    a, b = run_scenario(spec), run_scenario(spec)
    assert a.completed and b.completed
    assert a.events_fired == b.events_fired
    assert a.blocks_requested == b.blocks_requested
    assert a.blocks_migrated == b.blocks_migrated
    assert a.checks_run == b.checks_run


@pytest.mark.parametrize("seed", range(8))
def test_seeded_scenarios_hold_invariants(seed):
    report = run_scenario(sample_spec(seed))
    assert report.completed, "scenario pipeline failed to drain"
    # checked after every spec tick, every fired event, and the final drain
    assert report.checks_run >= report.spec.ticks + len(report.events_fired) + 1
    # closure is also asserted inside check_final; re-state it as the
    # headline contract of the harness
    assert (
        report.blocks_migrated + report.blocks_forced + report.blocks_cancelled
        == report.blocks_requested
    )


def test_explicit_fault_matrix_scenario():
    # One scenario exercising most of the event taxonomy at fixed ticks.
    spec = ScenarioSpec(
        seed=11,
        ticks=20,
        n_regions=3,
        slots_per_region=16,
        n_blocks=10,
        topology="symmetric",
        workload="stream",
        leap_every=2,
        blocks_per_leap=4,
        writes_per_tick=2,
        faults=(
            FaultEvent("congest_link", tick=2, args={"src": 0, "dst": 1, "factor": 8.0}),
            FaultEvent("drain_region", tick=4, args={"region": 2}),
            FaultEvent("cancel_storm", tick=6, args={"frac": 0.5}),
            FaultEvent("write_burst", tick=8, args={"blocks": 6}),
            FaultEvent("restore_topology", tick=10),
            FaultEvent("out_of_slots", tick=12),
        ),
    )
    report = run_scenario(spec)
    assert report.completed
    assert len(report.events_fired) == 6


# -- sabotage: the checker must catch a deliberately re-introduced bug -------


def test_sabotage_clean_run_passes():
    report = run_scenario(SABOTAGE_SPEC)
    assert report.completed and report.blocks_forced == 8


def test_sabotage_is_caught_with_replayable_repro(tmp_path):
    with pytest.raises(InvariantViolation) as exc:
        run_with_repro(SABOTAGE_SPEC, str(tmp_path), sabotage="skip_quarantine")
    assert exc.value.invariant == "payload"
    assert "--replay" in str(exc.value)
    # the failing spec was serialized, and it round-trips to an identical run
    path = tmp_path / "last_failure.json"
    assert path.exists()
    replayed = ScenarioSpec.from_json(path.read_text())
    assert replayed == SABOTAGE_SPEC
    with pytest.raises(InvariantViolation):  # reproduces under the bug
        run_scenario(replayed, sabotage="skip_quarantine")
    assert run_scenario(replayed).completed  # and passes on the fixed code


def test_sabotage_failure_dumps_a_loadable_trace(tmp_path):
    # Chaos drivers always record telemetry, so a violation leaves a
    # Perfetto-loadable timeline of the run next to the serialized spec,
    # and the raised message points at both files.
    from repro.obs import validate_chrome_trace

    with pytest.raises(InvariantViolation) as exc:
        run_with_repro(SABOTAGE_SPEC, str(tmp_path), sabotage="skip_quarantine")
    traces = list(tmp_path.glob("chaos-*-trace.json"))
    assert len(traces) == 1
    assert traces[0].name in str(exc.value)
    trace = json.loads(traces[0].read_text())
    validate_chrome_trace(trace)
    evs = trace["traceEvents"]
    assert any(e["ph"] == "X" and e["name"] == "tick" for e in evs)
    assert any(e.get("cat") == "request" for e in evs)


def test_cli_replay_exit_codes(tmp_path):
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(SABOTAGE_SPEC.to_json())
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    clean = subprocess.run(
        [sys.executable, "-m", "repro.chaos", "--replay", str(spec_path)],
        cwd=root, env=env, capture_output=True, text=True,
    )
    assert clean.returncode == 0, clean.stderr
    broken = subprocess.run(
        [sys.executable, "-m", "repro.chaos", "--replay", str(spec_path),
         "--sabotage", "skip_quarantine"],
        cwd=root, env=env, capture_output=True, text=True,
    )
    assert broken.returncode == 1
    assert "payload" in (broken.stdout + broken.stderr)


# -- checker unit behaviour --------------------------------------------------


def test_checker_flags_leaked_slot():
    cfg = PoolConfig(2, 8, (4,))
    state = init_state(cfg, 4, np.zeros(4, np.int32))
    drv = MigrationDriver(state, cfg)
    # leak a slot by popping it from the free list behind the pipeline's back
    drv.ctx.free[1].take(1)
    with pytest.raises(InvariantViolation) as exc:
        InvariantChecker(drv).check_slots()
    assert exc.value.invariant == "slots"
    assert "leaked" in str(exc.value)


def test_checker_flags_payload_divergence():
    cfg = PoolConfig(2, 8, (4,))
    state = init_state(cfg, 4, np.zeros(4, np.int32))
    data = np.ones((4, 4), np.float32)
    import jax.numpy as jnp

    state = leap_write(state, jnp.arange(4), jnp.asarray(data))
    drv = MigrationDriver(state, cfg)
    wrong = data.copy()
    wrong[2] += 1.0
    with pytest.raises(InvariantViolation) as exc:
        InvariantChecker(drv).check_payload(expected=wrong)
    assert exc.value.invariant == "payload"
    InvariantChecker(drv).check_payload(expected=data)  # and the true copy passes


# -- drain_region idempotency (regression for this PR's fault.py fix) --------


def _tight_driver(huge_factor=1):
    # All of region 0 occupied; region 1 has exactly enough slots. Once the
    # evacuation is in flight every region-1 slot is reserved, so a re-plan
    # that counted in-flight victims would find zero capacity and blow up.
    cfg = PoolConfig(2, 8, (4,), huge_factor=huge_factor)
    state = init_state(cfg, 8, np.zeros(8, np.int32))
    data = np.arange(32, dtype=np.float32).reshape(8, 4)
    import jax.numpy as jnp

    state = leap_write(state, jnp.arange(8), jnp.asarray(data))
    drv = MigrationDriver(state, cfg, LeapConfig(initial_area_blocks=8))
    return drv, data


def test_drain_region_idempotent_while_in_flight():
    drv, data = _tight_driver()
    assert fault.drain_region(drv, 0) == 8
    drv.tick()  # epochs open: every block in flight, all of region 1 reserved
    assert drv.in_migration(np.arange(8)).all()
    # Regression: this used to re-plan the in-flight victims against zero
    # free capacity and raise "not enough surviving capacity to drain".
    assert fault.drain_region(drv, 0) == 0
    assert drv.default_session().drain()
    assert (drv.host_placement() == 1).all()
    InvariantChecker(drv).check_final(expected=data)


def test_drain_region_idempotent_tiered_huge_groups_mid_flight():
    drv, data = _tight_driver(huge_factor=4)
    assert drv.adopt_huge(np.arange(2)) == 2
    assert fault.drain_region(drv, 0) == 8
    drv.tick()
    assert fault.drain_region(drv, 0) == 0  # huge members in flight: no victims
    assert drv.default_session().drain()
    assert (drv.host_placement() == 1).all()
    InvariantChecker(drv).check_final(expected=data)


def test_drain_region_empty_region_is_noop():
    cfg = PoolConfig(2, 8, (4,))
    state = init_state(cfg, 4, np.ones(4, np.int32))
    drv = MigrationDriver(state, cfg)
    assert fault.drain_region(drv, 0) == 0  # nothing there: plans nothing


def test_chaos_driver_drain_refusal_is_not_a_violation():
    # drain_region onto a genuinely full survivor is refused (RuntimeError),
    # which the harness records rather than treating as a broken invariant.
    spec = ScenarioSpec(
        seed=5,
        ticks=6,
        n_regions=2,
        slots_per_region=8,
        n_blocks=8,
        workload="drain",
        faults=(FaultEvent("drain_region", tick=0, args={"region": 1}),),
    )
    cd = ChaosDriver(spec)
    report = cd.run()
    assert report.completed
    assert report.drain_refusals + len(report.events_fired) >= 1


# -- closed-loop tiering scenarios (DESIGN.md §13) ---------------------------


WSS_SPEC = ScenarioSpec(
    seed=7,
    ticks=40,
    n_regions=3,
    slots_per_region=16,
    n_blocks=12,
    topology="cxl_pooled",
    topology_args=(2, 1),
    workload="working_set_shift",
    tiering=True,
    tier_epoch=2,
    shift_every=10,
    hot_frac=0.25,
    reads_per_tick=8,
)


def test_working_set_shift_closes_the_loop():
    # The tiering policy is this workload's only migration source: a clean
    # run must still migrate blocks (promotions chase the rotating hot set)
    # while the hysteresis monitor holds alongside every other invariant.
    report = run_scenario(WSS_SPEC)
    assert report.completed
    assert report.blocks_migrated > 0, "tiering policy never moved a block"
    again = run_scenario(WSS_SPEC)
    assert again.blocks_migrated == report.blocks_migrated  # deterministic


def test_working_set_shift_spec_roundtrips():
    assert ScenarioSpec.from_json(WSS_SPEC.to_json()) == WSS_SPEC
    with pytest.raises(ValueError):
        ScenarioSpec(hot_frac=0.0).validate()
    with pytest.raises(ValueError):
        ScenarioSpec(tier_epoch=0).validate()


def test_working_set_shift_under_faults():
    # Fault events are phase shifts: they clear the hysteresis history, so
    # fault-driven re-tiering does not count against the policy's cooldown.
    spec = ScenarioSpec(
        seed=9,
        ticks=30,
        n_regions=3,
        slots_per_region=16,
        n_blocks=12,
        topology="cxl_pooled",
        topology_args=(2, 1),
        workload="working_set_shift",
        tiering=True,
        tier_epoch=2,
        shift_every=8,
        faults=(
            FaultEvent("out_of_slots", tick=12),
            FaultEvent("congest_link", tick=20, args={"src": 0, "dst": 2, "factor": 4.0}),
        ),
    )
    report = run_scenario(spec)
    assert report.completed
    assert len(report.events_fired) == 2


def test_hysteresis_monitor_flags_ping_pong():
    from repro.chaos import HysteresisMonitor

    placement = np.zeros(4, np.int32)
    mon = HysteresisMonitor(placement, window=16, max_moves=2)
    p = placement.copy()
    # block 1 bounces 0 -> 1 -> 0 -> 1 inside one window: third move trips
    p[1] = 1
    mon.observe(1, p)
    p[1] = 0
    mon.observe(4, p)
    p[1] = 1
    with pytest.raises(InvariantViolation, match="tiering_hysteresis"):
        mon.observe(7, p)


def test_hysteresis_monitor_phase_shift_resets_and_window_expires():
    from repro.chaos import HysteresisMonitor

    placement = np.zeros(2, np.int32)
    mon = HysteresisMonitor(placement, window=8, max_moves=1)
    p = placement.copy()
    p[0] = 1
    mon.observe(0, p)
    mon.phase_shift()  # rotation/fault: history cleared
    p[0] = 0
    mon.observe(1, p)  # would be the 2nd move without the reset
    p[0] = 1
    mon.observe(20, p)  # 1st move long outside the window: fine too
    p[0] = 0
    with pytest.raises(InvariantViolation):
        mon.observe(22, p)
