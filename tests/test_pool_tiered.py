"""Two-tier pool integration: huge blocks migrate as single areas through the
fused dispatch path (one contiguous-run copy, not G gathers), demote under
sustained write pressure and still fully migrate, and promote/demote cleanly
from the serving engine (acceptance criteria of the two-tier redesign)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LeapConfig,
    MigrationDriver,
    PoolConfig,
    group_dirty,
    huge_read,
    init_state,
    leap_write,
    migrator,
)
from repro.kernels import ops

G = 4


def make_tiered(n_blocks=16, n_regions=2, slots=32, block_shape=(1, 8), seed=0,
                adopt=True, **leap_kw):
    cfg = PoolConfig(n_regions, slots, block_shape, huge_factor=G)
    state = init_state(cfg, n_blocks, np.zeros(n_blocks, np.int32))
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((n_blocks,) + block_shape).astype(np.float32)
    state = leap_write(state, jnp.arange(n_blocks), jnp.asarray(data))
    drv = MigrationDriver(state, cfg, LeapConfig(
        initial_area_blocks=8, budget_blocks_per_tick=16, **leap_kw))
    if adopt:
        assert drv.adopt_huge(np.arange(n_blocks // G)) == n_blocks // G
    return cfg, drv, data


# ---------------------------------------------------------------------------
# Kernels / programs
# ---------------------------------------------------------------------------


def test_copy_runs_matches_oracle():
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(16, 8, 128)).astype(np.float32))
    src = jnp.asarray([0, 8], jnp.int32)
    dst = jnp.asarray([4, 12], jnp.int32)
    got = ops.copy_runs_impl(pool, src, dst, run=4, impl="pallas_interpret")
    want = ops.copy_runs_impl(pool, src, dst, run=4, impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got)[4:8], np.asarray(pool)[0:4])


def test_commit_groups_all_or_nothing():
    """One dirty member rejects the WHOLE huge block (huge-page semantics)."""
    cfg, drv, data = make_tiered()
    state = drv.state
    members = jnp.arange(G)  # group 0
    state = migrator.begin_areas(state, members)
    state = leap_write(state, jnp.asarray([2]), jnp.zeros((1, 1, 8)))  # dirty one
    assert bool(group_dirty(state, jnp.asarray([0]), G)[0])
    state, verdict = migrator.commit_groups(
        state, members, jnp.asarray([1]), jnp.asarray([0]), group=G
    )
    assert verdict.tolist() == [True]
    table = np.asarray(state.table)
    assert (table[:G, 0] == 0).all()  # nothing flipped, not even clean members


def test_huge_read_returns_contiguous_payload():
    cfg, drv, data = make_tiered()
    got = np.asarray(huge_read(drv.state, jnp.asarray([0, 2]), G))
    np.testing.assert_array_equal(got[0], data[0:G])
    np.testing.assert_array_equal(got[1], data[2 * G : 3 * G])


# ---------------------------------------------------------------------------
# Driver: huge migration as one area through the fused path
# ---------------------------------------------------------------------------


def test_huge_block_migrates_as_single_run_copy():
    """Acceptance: a huge block goes through the fused dispatch path as ONE
    contiguous-run copy — under megastep dispatch, 2 programs total (one
    megastep carrying begin + the run copy, one carrying the grouped
    commit), all bytes through the run program, and one all-or-nothing
    commit."""
    cfg, drv, data = make_tiered()
    assert drv.request([0], 1) == G  # touching one member migrates the block
    assert drv.drain()
    s = drv.stats
    assert s.dispatches == 2, "one begin+run-copy megastep + one commit megastep"
    assert s.huge_areas_committed == 1
    assert s.bytes_copied == s.bytes_copied_huge == G * cfg.block_bytes
    assert s.blocks_migrated == G
    table = drv.host_table()
    assert (table[:G, 0] == 1).all()
    start = table[0, 1]
    assert start % G == 0  # buddy alignment survives migration
    assert (table[np.arange(G), 1] == start + np.arange(G)).all()
    assert drv.verify_mirror() and drv.verify_tiers()
    np.testing.assert_array_equal(np.asarray(drv.read(np.arange(G))), data[:G])


def test_huge_drain_full_pool():
    cfg, drv, data = make_tiered()
    drv.request(np.arange(16), 1)
    assert drv.drain()
    assert drv.stats.huge_areas_committed == 4
    assert (drv.host_placement() == 1).all()
    assert drv.verify_tiers()
    np.testing.assert_array_equal(np.asarray(drv.read(np.arange(16))), data)


def test_legacy_dispatch_path_supports_huge():
    cfg, drv, data = make_tiered(fused_dispatch=False)
    drv.request(np.arange(16), 1)
    assert drv.drain()
    assert drv.stats.huge_areas_committed == 4
    assert drv.verify_mirror() and drv.verify_tiers()
    np.testing.assert_array_equal(np.asarray(drv.read(np.arange(16))), data)


# ---------------------------------------------------------------------------
# Demotion under writes (paper §4.2)
# ---------------------------------------------------------------------------


def test_sustained_writes_demote_then_fully_migrate():
    """Acceptance: a huge-area commit rejected under sustained writes demotes
    to small blocks that all eventually migrate (splitting/forcing as
    needed), with no write lost."""
    cfg, drv, data = make_tiered(
        demote_after_attempts=2, max_attempts_before_force=6
    )
    drv.request(np.arange(16), 1)
    rng = np.random.default_rng(1)
    expected = data.copy()
    steps = 0
    while not drv.done and steps < 500:
        drv.tick()
        ids = np.asarray([1, 6])  # hammer members of groups 0 and 1
        vals = rng.standard_normal((2, 1, 8)).astype(np.float32)
        drv.write(jnp.asarray(ids), jnp.asarray(vals))
        expected[ids] = vals
        steps += 1
    assert drv.drain()
    assert drv.stats.demotions >= 1
    assert not drv.tiers.tier[0] or not drv.tiers.tier[1]  # a hot group split
    assert (drv.host_placement() == 1).all(), "demoted blocks must still migrate"
    np.testing.assert_array_equal(np.asarray(drv.read(np.arange(16))), expected)
    assert drv.verify_mirror() and drv.verify_tiers()


def test_fragmented_destination_demotes():
    """No contiguous run at the destination (>= G free but fragmented) splits
    the huge block instead of stalling."""
    cfg, drv, data = make_tiered(n_blocks=8, slots=16)
    # fragment region 1: pin every other slot via direct buddy reservation
    drv.debug_free_list(1).reserve(np.arange(0, 16, 2))
    assert drv.debug_free_list(1).take_run() is None and drv.free_slots(1) == 8
    drv.request(np.arange(G), 1)
    assert drv.drain()
    assert drv.stats.demotions == 1
    assert not drv.tiers.tier[0]
    assert (drv.host_placement()[:G] == 1).all()
    assert drv.verify_mirror()


# ---------------------------------------------------------------------------
# Promotion (coalescing) and adoption
# ---------------------------------------------------------------------------


def test_promote_requires_aligned_fully_resident_run():
    cfg, drv, data = make_tiered(adopt=False)
    # scatter group 1's members across regions
    drv.request([4, 5], 1)
    assert drv.drain()
    assert not drv.promote_group(1)  # split residency: refused
    assert drv.promote_group(0)  # fully resident on region 0: promoted
    assert drv.tiers.tier[0] and not drv.tiers.tier[1]
    assert drv.verify_mirror() and drv.verify_tiers()
    np.testing.assert_array_equal(np.asarray(drv.read(np.arange(G))), data[:G])
    # bring group 1 home and coalesce it too
    drv.request([4, 5], 0)
    assert drv.drain()
    assert drv.promote_group(1)
    assert drv.verify_tiers()
    np.testing.assert_array_equal(
        np.asarray(drv.read(np.arange(2 * G))), data[: 2 * G]
    )


def test_promotion_refused_while_migrating_or_hot():
    cfg, drv, data = make_tiered(adopt=False, promote_cold_ticks=4)
    drv.request(np.arange(G), 1)
    assert not drv.promote_group(0)  # under migration
    assert drv.drain()
    drv.write(jnp.asarray([0]), jnp.zeros((1, 1, 8)))
    assert not drv.promote_group(0)  # too hot (written this tick)
    for _ in range(5):
        drv.tick()
    assert drv.promote_group(0)  # cold now
    assert drv.verify_tiers()


def test_auto_promote_per_tick():
    cfg, drv, _ = make_tiered(adopt=False, promote_per_tick=2)
    assert drv.promote_candidates() == [0, 1, 2, 3]
    drv.tick()
    drv.tick()
    assert drv.stats.promotions == 4
    assert drv.tiers.tier.all()
    assert drv.verify_tiers()


def test_adopt_huge_requires_contiguity():
    cfg, drv, _ = make_tiered(adopt=False)
    # swap two members' slots: send both away, bring them home in reverse
    # order so the lowest-address-fit crosses them over
    drv.request([0, 1], 1)
    assert drv.drain()
    drv.request([1], 0)
    assert drv.drain()
    drv.request([0], 0)
    assert drv.drain()
    assert drv.host_table()[0, 1] != 0  # block 0 no longer on slot 0
    adopted = drv.adopt_huge(np.arange(4))
    assert adopted == 3  # group 0 is no longer an ascending contiguous run
    assert not drv.tiers.tier[0] and drv.tiers.tier[1:].all()
    assert drv.verify_tiers()


# ---------------------------------------------------------------------------
# Serving engine end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    from repro.configs.base import get_config
    from repro.configs.smoke import reduce
    from repro.models import lm

    cfg = dataclasses.replace(reduce(get_config("granite_3_2b")), n_layers=2)
    params = lm.init_params(jax.random.key(0), cfg)
    return cfg, params


def _engine(model, **kw):
    from repro.serving.engine import PagedConfig, PagedEngine

    cfg, params = model
    pcfg = PagedConfig(
        block_tokens=4, max_blocks_per_seq=16, n_regions=2, slots_per_region=64, **kw
    )
    return PagedEngine(cfg, params, pcfg)


def test_engine_promotes_growing_sequences_and_matches_small_pool(model):
    eng = _engine(model, huge_factor=2)
    ref = _engine(model, huge_factor=1)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, model[0].vocab_size, size=9)
    sid, rid = eng.admit(prompt), ref.admit(prompt)
    for _ in range(12):
        eng.decode([sid])
        ref.decode([rid])
    assert eng.driver.stats.promotions >= 1, "long KV must coalesce to huge"
    assert eng.seqs[sid].promoted
    assert eng.seqs[sid].tokens == ref.seqs[rid].tokens
    assert eng.driver.verify_mirror() and eng.driver.verify_tiers()


def test_engine_huge_rebalance_while_decoding(model):
    eng = _engine(model, huge_factor=2)
    ref = _engine(model, huge_factor=1)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, model[0].vocab_size, size=9)
    sid, rid = eng.admit(prompt), ref.admit(prompt)
    for _ in range(10):
        eng.decode([sid])
    assert eng.driver.stats.promotions >= 1
    moved = np.asarray(eng.seqs[sid].block_ids)  # what rebalance requests
    eng.rebalance(sid, 1)
    steps = 0
    while not eng.driver.done and steps < 200:
        eng.tick()
        eng.decode([sid])
        steps += 1
    assert eng.drain()
    assert eng.driver.stats.huge_areas_committed >= 1
    table = eng.driver.host_table()
    # every page that existed at rebalance time landed on region 1 (frontier
    # pages allocated afterwards may still draw from region-0 spare groups)
    assert (table[moved, 0] == 1).all()
    assert eng.driver.verify_mirror() and eng.driver.verify_tiers()
    for _ in range(10 + steps):
        ref.decode([rid])
    assert eng.seqs[sid].tokens == ref.seqs[rid].tokens


def test_engine_demotion_under_live_appends(model):
    """Acceptance: demotion exercised end-to-end from serving — eager
    promotion puts the append frontier inside a huge block, live decode keeps
    dirtying it during rebalance, the commit rejects and the block demotes;
    decode output stays exact throughout."""
    leap = dataclasses.replace(
        LeapConfig(), demote_after_attempts=2, budget_blocks_per_tick=4
    )
    eng = _engine(model, huge_factor=2, promote_eager=True, leap=leap)
    ref = _engine(model, huge_factor=1)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, model[0].vocab_size, size=9)
    sid, rid = eng.admit(prompt), ref.admit(prompt)
    for _ in range(4):
        eng.decode([sid])
    assert eng.driver.stats.promotions >= 1
    eng.rebalance(sid, 1)
    steps = 0
    while not eng.driver.done and steps < 300:
        eng.tick()
        eng.decode([sid])  # live appends dirty the frontier huge block
        steps += 1
    assert eng.driver.done
    assert eng.driver.stats.demotions >= 1, "frontier huge block must demote"
    table = eng.driver.host_table()
    assert (table[np.asarray(eng.seqs[sid].block_ids), 0] == 1).all(), (
        "demoted blocks must all eventually migrate"
    )
    assert eng.driver.verify_mirror() and eng.driver.verify_tiers()
    for _ in range(4 + steps):
        ref.decode([rid])
    assert eng.seqs[sid].tokens == ref.seqs[rid].tokens
