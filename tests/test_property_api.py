"""Hypothesis property tests for the handle-based API: arbitrary
leap/cancel/write interleavings terminate, account exactly, preserve data,
and never leak pool slots.

Kept separate (importorskip) so the tier-1 suite collects without the
optional ``hypothesis`` dev dependency.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.api import LeapSession
from repro.chaos import InvariantChecker
from repro.core import (
    LeapConfig,
    MigrationDriver,
    PoolConfig,
    init_state,
    leap_write,
)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(4, 20),
    n_regions=st.sampled_from([2, 3]),
    ops=st.integers(10, 40),
)
def test_property_leap_cancel_write_interleavings(seed, n_blocks, n_regions, ops):
    rng = np.random.default_rng(seed)
    cfg = PoolConfig(n_regions, n_blocks * 2, (4,))
    state = init_state(cfg, n_blocks, np.zeros(n_blocks, np.int32))
    data = rng.normal(size=(n_blocks, 4)).astype(np.float32)
    state = leap_write(state, jnp.arange(n_blocks), jnp.asarray(data))
    drv = MigrationDriver(
        state,
        cfg,
        LeapConfig(
            initial_area_blocks=4,
            chunk_blocks=2,
            budget_blocks_per_tick=4,
            max_attempts_before_force=3,
        ),
    )
    sess = LeapSession(drv)
    expected = data.copy()
    handles = []
    for _ in range(ops):
        op = rng.integers(0, 4)
        if op == 0:  # leap a random subset somewhere, at a random priority
            ids = rng.choice(n_blocks, size=int(rng.integers(1, n_blocks + 1)),
                             replace=False)
            handles.append(
                sess.leap(ids, int(rng.integers(0, n_regions)),
                          priority=int(rng.integers(0, 3)))
            )
        elif op == 1 and handles:  # cancel a random (possibly done) handle
            handles[int(rng.integers(0, len(handles)))].cancel()
        elif op == 2:  # concurrent writes
            k = int(rng.integers(1, 4))
            ids = rng.choice(n_blocks, size=k, replace=False)
            vals = rng.normal(size=(k, 4)).astype(np.float32)
            drv.write(jnp.asarray(ids.astype(np.int32)), jnp.asarray(vals))
            expected[ids] = vals
        sess.tick()
        sess.poll()
    assert sess.drain(), "interleaved leap/cancel/write must terminate"

    # every handle terminal, with exact per-handle accounting
    for h in handles:
        assert h.done
        p = h.progress()
        assert p.committed + p.forced + p.cancelled == p.requested
        assert p.remaining == 0
    # the shared standing invariants: global accounting closure, slot
    # conservation, mirror consistency, and no write lost (payload vs shadow)
    InvariantChecker(drv).check_final(expected=expected)
