"""Serving demo: batched decode over the leap-paged KV cache with live
replica rebalancing.

Admits a batch of prompts across two regions, decodes while one sequence's
KV pages migrate to the other region, and verifies outputs are identical to
an undisturbed run (the paper's correctness property, on the serving path).

    PYTHONPATH=src python examples/serve_paged.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs.base import get_config
from repro.configs.smoke import reduce
from repro.core import LeapConfig
from repro.models import lm
from repro.serving.engine import PagedConfig, PagedEngine


def main():
    cfg = dataclasses.replace(reduce(get_config("qwen2_7b")), n_layers=2)
    params = lm.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (6, 11, 17, 9)]

    def serve(live_migration: bool):
        eng = PagedEngine(
            cfg, params,
            PagedConfig(block_tokens=4, max_blocks_per_seq=32, n_regions=2,
                        slots_per_region=128,
                        leap=LeapConfig(initial_area_blocks=2, chunk_blocks=1,
                                        budget_blocks_per_tick=2)),
        )
        sids = [eng.admit(p, region=i % 2) for i, p in enumerate(prompts)]
        handle = None
        if live_migration:
            handle = eng.rebalance(sids[0], dst_region=1)
            print(f"rebalancing seq {sids[0]}: {handle.requested} KV pages "
                  f"region 0 -> 1, live ({handle.status.name})")
        outs = []
        for step in range(16):
            if live_migration:
                eng.tick()
            outs.append(tuple(eng.decode(sids)))
        if live_migration:
            assert handle.wait()
            p = handle.progress()
            assert p.committed + p.forced + p.cancelled == p.requested
            s = eng.facade.snapshot_stats()
            print(f"migration: {handle.status.name} committed={p.committed} "
                  f"forced={p.forced} dirty_rejections={s.dirty_rejections}")
        return outs

    base = serve(live_migration=False)
    live = serve(live_migration=True)
    assert base == live, "live migration must not change decode outputs"
    print("16 decode steps x 4 sequences: outputs identical under live page migration ✓")
    print("sample tokens:", [t[:2] for t in base[:4]])


if __name__ == "__main__":
    main()
