"""End-to-end training driver: a ~100M-parameter granite-family model
trained for a few hundred steps with checkpoints, restart-after-failure,
and morsel-based data placement.

CPU note: this container has one core, so the default preset is a ~15M
model for a few hundred steps (minutes); pass ``--preset 100m`` for the
full-size run (same code path, just bigger dims / longer wall time).

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--preset 15m]
    PYTHONPATH=src python examples/train_e2e.py --chaos   # kill + restart
"""

import argparse
import dataclasses
import shutil
import tempfile

from repro.configs.base import get_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # (d_model, n_layers, n_heads, kv, head_dim, d_ff, vocab, seq, batch)
    "15m": (256, 8, 8, 4, 32, 1024, 8192, 256, 8),
    "100m": (768, 12, 12, 4, 64, 3072, 32000, 512, 8),
}


def make_cfg(preset: str):
    d, l, h, kv, hd, ff, v, seq, batch = PRESETS[preset]
    base = get_config("granite_3_2b")
    cfg = dataclasses.replace(
        base,
        d_model=d, n_layers=l, n_heads=h, n_kv_heads=kv, head_dim=hd,
        d_ff=ff, vocab_size=v,
        param_dtype="float32", compute_dtype="float32",
        name=f"granite_{preset}", attn_chunk=128,
    )
    return cfg, seq, batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=PRESETS, default="15m")
    ap.add_argument("--chaos", action="store_true", help="fail mid-run and restart")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg, seq, batch = make_cfg(args.preset)
    from repro.models.lm import count_params

    print(f"model: {cfg.name}  params={count_params(cfg) / 1e6:.1f}M  "
          f"seq={seq} batch={batch} steps={args.steps}")
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="leapjax_e2e_")
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, batch, seed=0))
    tcfg = TrainConfig(
        n_micro=2,
        optimizer=OptimizerConfig(peak_lr=3e-3, warmup_steps=20,
                                  total_steps=args.steps),
    )
    mk = lambda: Trainer(
        cfg, tcfg,
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      ckpt_dir=ckpt_dir, log_every=10),
        data,
    )

    tr = mk()
    log = lambda step, m: print(
        f"step {step:5d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}"
    )
    if args.chaos:
        try:
            tr.run(on_step=log, fail_at=args.steps // 2)
        except RuntimeError as e:
            print(f"!! {e} — restarting from the last committed checkpoint")
        tr = mk()
        resumed = tr.restore_or_init()
        print(f"resumed from step {resumed}")
    hist = tr.run(on_step=log)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f}  ({'improved ✓' if last < first else 'NO IMPROVEMENT ✗'})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
