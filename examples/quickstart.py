"""Quickstart: the leap migration engine in 60 lines.

Creates a 2-region pool holding 64 blocks, starts an asynchronous migration
through the handle-based session API while a writer keeps mutating blocks,
and shows the dirty-retry protocol converging with zero lost writes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.api import HandleStatus, LeapSession
from repro.core import LeapConfig, MigrationDriver, PoolConfig, init_state, leap_write


def main():
    # a pool of 64 logical blocks (4 KB each), all resident on region 0
    cfg = PoolConfig(n_regions=2, slots_per_region=80, block_shape=(1, 1024))
    state = init_state(cfg, n_blocks=64, initial_regions=np.zeros(64, np.int32))
    rng = np.random.default_rng(0)
    data = rng.standard_normal((64, 1, 1024), dtype=np.float32)
    state = leap_write(state, jnp.arange(64), jnp.asarray(data))

    drv = MigrationDriver(
        state,
        cfg,
        LeapConfig(
            initial_area_blocks=16,  # start coarse ("16MB sweet spot")
            chunk_blocks=4,  # copy 4 blocks per dispatch
            budget_blocks_per_tick=8,  # async budget per tick
            max_attempts_before_force=4,  # write-through escalation
        ),
    )
    session = LeapSession(drv)

    print("leaping all 64 blocks: region 0 -> region 1 (async, tracked)")
    handle = session.leap(
        np.arange(64),
        dst_region=1,
        on_done=lambda h: print(f"  on_done fired: {h.status.name}"),
    )

    step = 0
    expected = data.copy()
    while not handle.done:
        session.tick()  # one asynchronous migration slice
        # ... meanwhile the application keeps writing (concurrent mutations!)
        ids = rng.choice(64, size=2, replace=False)
        vals = rng.standard_normal((2, 1, 1024), dtype=np.float32)
        drv.write(jnp.asarray(ids.astype(np.int32)), jnp.asarray(vals))
        expected[ids] = vals
        step += 1
    assert handle.wait()  # harvest the final verdicts

    p = handle.progress()
    print(f"done after {step} ticks: committed={p.committed} forced={p.forced}")
    assert p.committed + p.forced + p.cancelled == p.requested == 64
    assert handle.status == HandleStatus.COMMITTED
    stats = session.facade.snapshot_stats()
    print(f"dirty rejections={stats.dirty_rejections} splits={stats.splits} "
          f"extra copied={stats.extra_bytes(cfg.block_bytes)} bytes")
    placement = session.facade.placement()
    assert (placement == 1).all(), "all blocks must be on region 1"
    got = np.asarray(drv.read(jnp.arange(64)))
    assert np.array_equal(got, expected), "no write may be lost"
    print("placement verified; every concurrent write preserved ✓")


if __name__ == "__main__":
    main()
