"""Paper §7 scenario: morsel-driven TPC-H on the wrong region.

lineitem morsels sit on region 0; the worker on region 1 leap-migrates them
into pooled memory and runs Q1/Q6 five times — while a transactional writer
keeps updating L_ORDERKEY.  Shows migration time, per-query speed-up trend,
and result correctness under concurrent writes.

    PYTHONPATH=src python examples/tpch_morsels.py
"""

import time

import jax
import numpy as np

from repro.core import LeapConfig
from repro.data import tpch
from repro.data.morsels import MorselStore


def main():
    n_rows = 131_072
    data = tpch.gen_lineitem(n_rows, seed=0)
    store = MorselStore.create(
        data, rows_per_morsel=1024, n_regions=2, initial_region=0,
        leap=LeapConfig(initial_area_blocks=32, chunk_blocks=16,
                        budget_blocks_per_tick=32),
    )
    print(f"lineitem: {n_rows} rows in {store.n_morsels} morsels on region 0")

    want_q1 = tpch.q1_reference(data, 2400.0)
    rng = np.random.default_rng(1)

    # Session API: leap() returns a LeapHandle future; the sealed facade is
    # the read-only observation surface (no driver internals needed).
    facade = store.session.facade
    t0 = time.perf_counter()
    handle = store.leap(np.arange(store.n_morsels), dst_region=1)
    while not handle.done:
        store.tick()
        store.write_random_fields(rng, 8, tpch.ORDERKEY, -1.0)  # OLTP writer
    assert handle.wait()
    t_mig = time.perf_counter() - t0
    s = facade.snapshot_stats()
    print(f"migration: {t_mig * 1e3:.1f} ms  (retries={s.dirty_rejections}, "
          f"splits={s.splits}, extra={s.extra_bytes(facade.pool_cfg.block_bytes)}B)")
    assert (store.placement() == 1).all() and facade.verify_mirror()

    for q, param in (("q1", 2400.0), ("q6", 730.0)):
        ts = []
        for i in range(5):
            t0 = time.perf_counter()
            r = tpch.run_query(store, q, param)
            jax.block_until_ready(r)
            ts.append(time.perf_counter() - t0)
            store.write_random_fields(rng, 8, tpch.ORDERKEY, -1.0)
        print(f"{q}: {['%.1fms' % (t * 1e3) for t in ts]}")

    got = np.asarray(tpch.run_query(store, "q1", 2400.0), np.float64)
    np.testing.assert_allclose(got, want_q1, rtol=1e-3)
    print("Q1 result matches reference despite concurrent writes ✓")


if __name__ == "__main__":
    main()
